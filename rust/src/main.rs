//! `sa-lowpower` CLI — the L3 entry point.
//!
//! Subcommands regenerate the paper's figures and run the system:
//!
//! ```text
//! sa-lowpower fig2      [--net resnet50|mobilenet] [--seed N] [--csv-dir D]
//! sa-lowpower fig4      [--tiles N] [--threads N] [--seed N] [--csv-dir D]
//! sa-lowpower fig5      [--tiles N] [--threads N] [--seed N] [--csv-dir D]
//! sa-lowpower headline  [--tiles N] [--threads N] [--seed N]
//! sa-lowpower ablation  [--net X] [--tiles N] [--threads N] [--seed N]
//! sa-lowpower area      [--rows N] [--cols N]
//! sa-lowpower simulate  [--m N] [--k N] [--n N] [--sparsity F] [--config C]
//!                       [--backend analytic|cycle]
//! sa-lowpower e2e       [--requests N] [--artifacts DIR] [--seed N]
//! sa-lowpower serve     [--jobs N] [--threads N] [--engine-cap N]
//!                       [--cache off|memory|persistent]
//!                       [--cache-budget BYTES] [--cache-dir DIR]
//!                       [--summary-json PATH]
//! ```
//!
//! All power estimation routes through [`sa_lowpower::engine::SaEngine`];
//! `--backend` selects the estimator on the commands that expose it, and
//! `--json-dir` writes the machine-readable sweep report next to the CSVs.

use anyhow::{anyhow, bail, Result};

use sa_lowpower::coding::CodingStack;
use sa_lowpower::coordinator::{
    synthetic_image, AnalysisOptions, InferenceServer, SweepReport, TinycnnParams,
};
use sa_lowpower::engine::{
    serve_loop, BackendKind, CachePolicy, ConfigRegistry, ConfigSet, EngineError,
    EstimatorBackend, FaultPlan, LayerJob, SaEngine, ServeOptions,
    DEFAULT_ENGINE_CAP,
};
use sa_lowpower::power::AreaModel;
use sa_lowpower::report::{ablation_table, fig2_tables, fig45_table, headline_table, Table};
use sa_lowpower::sa::{Dataflow, SaConfig, Tile};
use sa_lowpower::stats::WeightFieldStats;
use sa_lowpower::util::cli::Args;
use sa_lowpower::util::Rng64;
use sa_lowpower::workload::{gen_weights, Network};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        // Typed engine failures carry stable exit codes (invalid-spec=2,
        // …, internal=10); anything untyped is the generic 1.
        let code = e
            .downcast_ref::<EngineError>()
            .map(EngineError::exit_code)
            .unwrap_or(1);
        std::process::exit(code);
    }
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("fig2") => fig2(args),
        Some("fig4") => fig45(args, "resnet50"),
        Some("fig5") => fig45(args, "mobilenet"),
        Some("headline") => headline(args),
        Some("ablation") => ablation(args),
        Some("area") => area(args),
        Some("simulate") => simulate(args),
        Some("e2e") => e2e(args),
        Some("serve") => serve(args),
        Some("transformer") => transformer(args),
        Some("trace") => trace(args),
        Some("ddcg") => ddcg(args),
        Some("pruning") => pruning(args),
        Some("sweep-size") => sweep_size(args),
        Some(other) => bail!("unknown subcommand '{other}'\n{}", usage()),
        None => {
            println!("{}", usage());
            Ok(())
        }
    }
}

/// Usage text; the config and backend lists derive from the engine
/// registry, so they can never drift from what the code accepts.
fn usage() -> String {
    format!(
        "usage: sa-lowpower <subcommand> [options]
  fig2 | fig4 | fig5 | headline | ablation | area   paper figures/claims
  simulate | e2e | trace | serve                    drivers
  ddcg | pruning | sweep-size | transformer         extension experiments
  --config   one of: {configs}
  --coding   a composed codec-stack spec, e.g. 'w:zvcg+bic-mantissa,i:zvcg'
             (grammar: <edge>:<codec>+<codec>,... — edges w|i; codecs zvcg,
             bic-mantissa|full|segmented|exponent[-mt], ddcg16-g<N>)
  --backend  one of: {backends}   (estimator: analytic model vs cycle sim)
  --dataflow one of: {dataflows}   (register movement: weight- vs output-stationary)
  --net      one of: {nets} (where applicable)
  --json-dir DIR                 write machine-readable sweep reports
  --no-specialize                force the generic codec interpreter instead of
             the fused pricing kernels (bit-identical results; perf triage)
  --fault-inject SPEC            simulate only: arm deterministic faults
             (grammar: <panic|error|delay:<ms>>@<layer|*>:<tile>[@<stage>],
              stages plan|price|worker; ';'-separated sites)
  --cache    serve only: off|memory|persistent result cache
             (with --cache-budget BYTES and --cache-dir DIR);
             job specs are 'key=value' lines on stdin, e.g.
             'net=resnet50 configs=paper backend=analytic tiles=4'
  --jobs N   serve only: overlap up to N jobs (default 1 = strict input
             order; output lines carry a \"line\" tag for reassociation)
  --engine-cap N                 serve only: engine-pool LRU capacity
  --summary-json PATH            serve only: write the drain summary
             (counters + latency/hit-rate histograms) as JSON
Typed engine failures exit with stable codes (invalid-spec=2 .. internal=10);
see README 'Error handling & operational limits'.
Reproduction of 'Low-Power Data Streaming in Systolic Arrays with Bus-Invert
Coding and Zero-Value Clock Gating' (MOCAST 2023). See README.md.",
        configs = ConfigRegistry::name_list(),
        backends = BackendKind::name_list(),
        dataflows = Dataflow::name_list(),
        nets = Network::name_list(),
    )
}

fn opts_from(args: &Args) -> Result<AnalysisOptions> {
    Ok(AnalysisOptions {
        seed: args.get_parse("seed", 0xCAFEu64).map_err(|e| anyhow!(e))?,
        max_tiles_per_layer: args.get_parse("tiles", 64usize).map_err(|e| anyhow!(e))?,
        max_dw_channels: args.get_parse("dw-channels", 4usize).map_err(|e| anyhow!(e))?,
        specialize: !args.flag("no-specialize"),
        sa: SaConfig { dataflow: dataflow_from(args)?, ..SaConfig::default() },
    })
}

fn threads_from(args: &Args) -> Result<usize> {
    let dflt = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    args.get_parse("threads", dflt).map_err(|e| anyhow!(e))
}

fn backend_from(args: &Args) -> Result<BackendKind> {
    match args.get("backend") {
        None => Ok(BackendKind::Analytic),
        Some(s) => s.parse().map_err(|e: String| anyhow!(e)),
    }
}

fn dataflow_from(args: &Args) -> Result<Dataflow> {
    match args.get("dataflow") {
        None => Ok(Dataflow::default()),
        Some(s) => s.parse().map_err(|e: String| anyhow!(e)),
    }
}

/// Resolve `--coding` (a registry name or a spec-grammar stack) and
/// append it to the base config set as an extra named column, so every
/// sweep/figure command can carry an arbitrary composed stack next to
/// the registry rows.
fn configs_from(args: &Args, base: ConfigSet) -> Result<ConfigSet> {
    match args.get("coding") {
        None => Ok(base),
        Some(spec) => {
            let (name, stack) =
                ConfigRegistry::resolve(spec).map_err(|e| anyhow!(e))?;
            // dedup by stack, not just name: a raw spec equal to an
            // existing column's design must not double the sweep work
            if base.iter().any(|(n, s)| *n == name || *s == stack) {
                return Ok(base);
            }
            Ok(base.with(name, stack))
        }
    }
}

/// One configured engine per invocation: options, configs, backend and
/// worker pool all come from the command line.
fn engine_from(args: &Args, configs: ConfigSet) -> Result<SaEngine> {
    let engine = SaEngine::builder()
        .options(opts_from(args)?)
        .configs(configs_from(args, configs)?)
        .backend(backend_from(args)?)
        .threads(threads_from(args)?)
        .build()?;
    Ok(engine)
}

fn maybe_csv(args: &Args, name: &str, t: &Table) -> Result<()> {
    if let Some(dir) = args.get("csv-dir") {
        let path = std::path::Path::new(dir).join(format!("{name}.csv"));
        t.write_csv(&path)?;
        println!("(wrote {})", path.display());
    }
    Ok(())
}

fn maybe_json(args: &Args, name: &str, sweep: &SweepReport) -> Result<()> {
    if let Some(dir) = args.get("json-dir") {
        let path = std::path::Path::new(dir).join(format!("{name}.json"));
        sweep.write_json(&path)?;
        println!("(wrote {})", path.display());
    }
    Ok(())
}

fn network_weights(net: &Network, seed: u64) -> Vec<f32> {
    let mut all = Vec::new();
    for (i, l) in net.layers.iter().enumerate() {
        all.extend(gen_weights(l, seed, i));
    }
    all
}

fn fig2(args: &Args) -> Result<()> {
    args.validate(&["net", "seed", "csv-dir"]).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 0xCAFEu64).map_err(|e| anyhow!(e))?;
    let nets = match args.get("net") {
        Some(n) => vec![n.to_string()],
        None => vec!["resnet50".into(), "mobilenet".into()],
    };
    for name in nets {
        let net = Network::by_name(&name)
            .ok_or_else(|| anyhow!("unknown network '{name}'"))?;
        let w = network_weights(&net, seed);
        let stats = WeightFieldStats::from_f32(&w);
        let (summary, exp, man) = fig2_tables(&name, &stats);
        println!("== Fig. 2 — weight value distributions: {name} ==");
        summary.print();
        println!();
        maybe_csv(args, &format!("fig2_{name}_summary"), &summary)?;
        maybe_csv(args, &format!("fig2_{name}_exponent_hist"), &exp)?;
        maybe_csv(args, &format!("fig2_{name}_mantissa_hist"), &man)?;
    }
    Ok(())
}

fn fig45(args: &Args, net_name: &str) -> Result<()> {
    args.validate(&[
        "tiles", "threads", "seed", "csv-dir", "json-dir", "dw-channels", "backend",
        "dataflow", "coding", "no-specialize",
    ])
    .map_err(|e| anyhow!(e))?;
    let engine = engine_from(args, ConfigSet::paper())?;
    let net = Network::by_name(net_name).unwrap();
    let figno = if net_name == "resnet50" { 4 } else { 5 };
    println!(
        "== Fig. {figno} — per-layer power, conventional vs proposed: {net_name} \
         ({} backend, {} dataflow) ==",
        engine.backend_name(),
        engine.dataflow()
    );
    let sweep = engine.sweep(&net)?;
    let t = fig45_table(&sweep, engine.sa());
    t.print();
    println!();
    println!(
        "overall dynamic power reduction: {:.1} %  (paper: {})",
        sweep.overall_savings_pct("baseline", "proposed"),
        if figno == 4 { "9.4 %" } else { "6.2 %" }
    );
    println!(
        "streaming activity reduction:    {:.1} %  (paper avg: ~29 %)",
        sweep.streaming_activity_reduction_pct("baseline", "proposed")
    );
    let (lo, hi) = sweep.per_layer_savings_range("baseline", "proposed");
    println!("per-layer savings range:         {lo:.1} % – {hi:.1} %  (paper: 1–19 %)");
    maybe_csv(args, &format!("fig{figno}_{net_name}"), &t)?;
    maybe_json(args, &format!("fig{figno}_{net_name}"), &sweep)?;
    Ok(())
}

fn headline(args: &Args) -> Result<()> {
    args.validate(&[
        "tiles", "threads", "seed", "csv-dir", "json-dir", "dw-channels", "backend",
        "dataflow", "coding", "no-specialize",
    ])
    .map_err(|e| anyhow!(e))?;
    let engine = engine_from(args, ConfigSet::paper())?;
    let resnet = engine.sweep(&Network::by_name("resnet50").unwrap())?;
    let mobilenet = engine.sweep(&Network::by_name("mobilenet").unwrap())?;
    println!("== Headline claims (paper §I / §IV) ==");
    let t = headline_table(&resnet, &mobilenet, engine.sa());
    t.print();
    maybe_csv(args, "headline", &t)?;
    maybe_json(args, "headline_resnet50", &resnet)?;
    maybe_json(args, "headline_mobilenet", &mobilenet)?;
    Ok(())
}

fn ablation(args: &Args) -> Result<()> {
    args.validate(&[
        "net", "tiles", "threads", "seed", "csv-dir", "json-dir", "dw-channels",
        "backend", "dataflow", "coding", "no-specialize",
    ])
    .map_err(|e| anyhow!(e))?;
    let engine = engine_from(args, ConfigSet::ablation())?;
    let name = args.get_or("net", "resnet50");
    let net = Network::by_name(name).ok_or_else(|| anyhow!("unknown network '{name}'"))?;
    println!(
        "== Ablation — coding design space on {name} ({} backend, {} dataflow) ==",
        engine.backend_name(),
        engine.dataflow()
    );
    let sweep = engine.sweep(&net)?;
    let t = ablation_table(&sweep, &engine.configs().names());
    t.print();
    maybe_csv(args, &format!("ablation_{name}"), &t)?;
    maybe_json(args, &format!("ablation_{name}"), &sweep)?;
    Ok(())
}

fn area(args: &Args) -> Result<()> {
    args.validate(&["rows", "cols", "config", "coding"]).map_err(|e| anyhow!(e))?;
    let rows = args.get_parse("rows", 16usize).map_err(|e| anyhow!(e))?;
    let cols = args.get_parse("cols", 16usize).map_err(|e| anyhow!(e))?;
    let stack = stack_from(args, "proposed")?;
    let model = AreaModel::default();
    println!(
        "== Area overhead of '{stack}' (paper §IV: 5.7 % at 16x16 for the \
         proposed stack, shrinking with size) =="
    );
    let mut t = Table::new(["array", "baseline_GE", "overhead_GE", "overhead_%"]);
    for n in [4usize, 8, 16, 32, 64, 128] {
        let a = model.area(n, n, &stack);
        t.row([
            format!("{n}x{n}"),
            format!("{:.0}", a.baseline_ge),
            format!("{:.0}", a.overhead_ge),
            format!("{:.2}", a.overhead_pct()),
        ]);
    }
    let custom = model.area(rows, cols, &stack);
    t.row([
        format!("{rows}x{cols} (requested)"),
        format!("{:.0}", custom.baseline_ge),
        format!("{:.0}", custom.overhead_ge),
        format!("{:.2}", custom.overhead_pct()),
    ]);
    t.print();
    Ok(())
}

/// The stack a single-stack command runs under: `--coding <spec>` wins,
/// else `--config <name-or-spec>`, else the given default registry row.
fn stack_from(args: &Args, default_name: &str) -> Result<CodingStack> {
    let chosen = args.get("coding").or_else(|| args.get("config"));
    let s = chosen.unwrap_or(default_name);
    ConfigRegistry::stack_by_name_or_spec(s).map_err(|e| anyhow!(e))
}

fn simulate(args: &Args) -> Result<()> {
    args.validate(&[
        "m", "k", "n", "sparsity", "config", "coding", "seed", "backend", "dataflow",
        "threads", "fault-inject", "no-specialize",
    ])
    .map_err(|e| anyhow!(e))?;
    let m = args.get_parse("m", 16usize).map_err(|e| anyhow!(e))?;
    let k = args.get_parse("k", 64usize).map_err(|e| anyhow!(e))?;
    let n = args.get_parse("n", 16usize).map_err(|e| anyhow!(e))?;
    let sp = args.get_parse("sparsity", 0.5f64).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 1u64).map_err(|e| anyhow!(e))?;
    let stack = stack_from(args, "proposed")?;

    let mut rng = Rng64::new(seed);
    let a: Vec<f32> = (0..m * k)
        .map(|_| if rng.chance(sp) { 0.0 } else { rng.normal() as f32 })
        .collect();
    let b: Vec<f32> = (0..k * n).map(|_| (rng.normal() * 0.08) as f32).collect();
    let tile = Tile::from_f32(&a, &b, m, k, n);

    let kind = backend_from(args)?;
    let dataflow = dataflow_from(args)?;
    let specialize = !args.flag("no-specialize");
    println!(
        "== simulate: {m}x{k}x{n} tile, sparsity {sp}, stack {stack}, \
         backend {}, dataflow {dataflow} ==",
        kind.name()
    );

    // --fault-inject: route the same GEMM through the engine's worker
    // pool with the plan armed. The doomed job fails with a typed error
    // while a clean resubmit on the *same* pool still succeeds —
    // demonstrating containment — and the typed error then becomes the
    // process exit code (the check.sh smoke run asserts on it).
    if let Some(spec) = args.get("fault-inject") {
        let plan = FaultPlan::parse(spec)?;
        let engine = SaEngine::builder()
            .seed(seed)
            .configs(configs_from(args, ConfigSet::paper())?)
            .backend(kind)
            .specialize(specialize)
            .dataflow(dataflow)
            .threads(threads_from(args)?)
            .fault_plan(plan)
            .build()?;
        let layer = sa_lowpower::workload::Layer::gemm_layer("sim", m, k, n, sp > 0.0);
        let doomed = engine
            .submit(LayerJob::with_data(layer.clone(), 0, a.clone(), b.clone()))?
            .wait();
        match doomed {
            Ok(_) => println!(
                "fault plan '{spec}' armed but did not fire on layer 'sim'; \
                 continuing with the clean run"
            ),
            Err(e) => {
                let clean = engine
                    .submit(LayerJob::with_data(layer, 0, a, b))?
                    .wait()?;
                println!(
                    "injected fault contained: job failed with [{}] {e}; clean \
                     resubmit on the same pool priced {} configs",
                    e.kind(),
                    clean.results.len()
                );
                return Err(e.into());
            }
        }
    }

    // Run both backends: the selected one produces the report, the other
    // cross-checks it (the backend contract says counts are bit-exact).
    let t0 = std::time::Instant::now();
    let cycle = BackendKind::Cycle
        .instantiate_with(specialize)
        .estimate(&tile, &stack, dataflow)?;
    let t_cycle = t0.elapsed();
    let t1 = std::time::Instant::now();
    let fast = BackendKind::Analytic
        .instantiate_with(specialize)
        .estimate(&tile, &stack, dataflow)?;
    let t_fast = t1.elapsed();
    if cycle != fast {
        bail!(
            "backend cross-check failed: analytic and cycle-accurate counts \
             diverge on the same tile (contract violation — see engine::backend)"
        );
    }
    println!("cycle-accurate sim: {t_cycle:?}; analytic model: {t_fast:?} (identical counts)");
    let counts = match kind {
        BackendKind::Analytic => fast,
        BackendKind::Cycle => cycle,
    };
    println!("{counts:#?}");
    let sa = SaConfig::default().with_coding(stack);
    let e = sa.energy.energy(&counts);
    println!(
        "energy: total {:.3} nJ  (streaming {:.3} nJ, compute {:.3} nJ)",
        e.total() * 1e-6,
        e.streaming() * 1e-6,
        e.compute() * 1e-6
    );
    println!("power @1GHz: {:.3} mW", sa.energy.power_mw(&counts, sa.clock_ghz));
    Ok(())
}

/// Debug driver: render a lane waveform (what the edge logic drives onto
/// one stream's bus, slot by slot).
fn trace(args: &Args) -> Result<()> {
    args.validate(&["k", "sparsity", "seed", "side", "coding"])
        .map_err(|e| anyhow!(e))?;
    let k = args.get_parse("k", 24usize).map_err(|e| anyhow!(e))?;
    let sp = args.get_parse("sparsity", 0.4f64).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 1u64).map_err(|e| anyhow!(e))?;
    let side = args.get_or("side", "west");
    use sa_lowpower::bf16::Bf16;
    use sa_lowpower::coding::EdgeStack;
    use sa_lowpower::sa::{render_trace, trace_lane};

    let mut rng = Rng64::new(seed);
    // Per-side defaults follow the paper's proposed assignment; --coding
    // takes a single-edge stack spec (e.g. 'zvcg+bic-mantissa').
    let (stream, default_stack): (Vec<Bf16>, &str) = match side {
        "west" => (
            (0..k)
                .map(|_| {
                    if rng.chance(sp) {
                        Bf16::ZERO
                    } else {
                        Bf16::from_f32(rng.normal().abs() as f32 * 0.5)
                    }
                })
                .collect(),
            "zvcg",
        ),
        "north" => (
            (0..k)
                .map(|_| Bf16::from_f32((rng.normal() * 0.08).clamp(-1.0, 1.0) as f32))
                .collect(),
            "bic-mantissa",
        ),
        other => bail!("--side must be west|north, got '{other}'"),
    };
    // --coding accepts either a bare single-edge stack
    // ('zvcg+bic-mantissa') or the full spec grammar / a registry name,
    // from which the --side edge is selected.
    let spec = args.get_or("coding", default_stack);
    let edge = if spec.contains(':') || ConfigRegistry::lookup(spec).is_some() {
        let full =
            ConfigRegistry::stack_by_name_or_spec(spec).map_err(|e| anyhow!(e))?;
        let picked = if side == "west" {
            full.west.clone()
        } else {
            full.north.clone()
        };
        if picked.is_empty() && full.has_overhead() {
            let other = if side == "west" { "north" } else { "west" };
            bail!(
                "--coding '{spec}' does not configure the {side} edge; \
                 pass --side {other} or a bare edge stack (e.g. 'zvcg')"
            );
        }
        picked
    } else {
        EdgeStack::parse(spec).map_err(|e| anyhow!(e))?
    };
    println!("== {side} lane trace: stack '{}' (K={k}) ==", edge.spec());
    let rows = trace_lane(&stream, &edge);
    print!("{}", render_trace(&rows));
    Ok(())
}

/// Extension: quantify the paper's §III-A(a) dismissal of data-driven
/// clock gating on real CNN streams.
fn ddcg(args: &Args) -> Result<()> {
    args.validate(&["seed", "len"]).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 0xCAFEu64).map_err(|e| anyhow!(e))?;
    let len = args.get_parse("len", 16384usize).map_err(|e| anyhow!(e))?;
    use sa_lowpower::bf16::Bf16;
    use sa_lowpower::coding::ddcg_analyze;

    println!("== DDCG (paper §III-A(a)): why data-driven clock gating fails on CNN streams ==");
    let mut rng = Rng64::new(seed);
    // CNN-like weight stream and ReLU-like input stream
    let weights: Vec<Bf16> = (0..len)
        .map(|_| Bf16::from_f32((rng.normal() * 0.08).clamp(-1.0, 1.0) as f32))
        .collect();
    let inputs: Vec<Bf16> = (0..len)
        .map(|_| {
            if rng.chance(0.5) {
                Bf16::ZERO
            } else {
                Bf16::from_f32(rng.normal().abs() as f32 * 0.5)
            }
        })
        .collect();

    // comparator ~0.6 fJ/bit/cycle (XOR + OR-tree share), ICG 0.5 fJ
    let (e_ff_clk, e_cmp, e_cg) = (0.9, 0.6, 0.5);
    for (name, stream) in [("weights", &weights), ("relu-inputs", &inputs)] {
        let mut t = Table::new([
            "group_bits",
            "clock_gated_%",
            "net_saving_fJ_per_value",
        ]);
        for g in [16usize, 8, 4, 2, 1] {
            let r = ddcg_analyze(stream, g);
            t.row([
                g.to_string(),
                format!("{:.1}", 100.0 * r.gating_effectiveness()),
                format!(
                    "{:+.2}",
                    r.net_saving_fj(e_ff_clk, e_cmp, e_cg) / len as f64
                ),
            ]);
        }
        println!("\n{name} stream ({len} values):");
        t.print();
    }
    println!(
        "\ncoarse groups never gate (values always change); fine groups gate\n\
         but the per-bit comparators cost more than the gated clocks save —\n\
         the paper's rationale for BIC + zero-value gating instead.\n\
         (Full-engine view: --config ddcg16-g4, or --coding 'w:ddcg16-g<N>,\
i:ddcg16-g<N>' on simulate/ablation.)"
    );
    Ok(())
}

/// Extension: the paper's future-work lever — weight pruning increases
/// weight zeros, which weight-side ZVCG can then exploit.
fn pruning(args: &Args) -> Result<()> {
    args.validate(&["seed", "tiles", "net"]).map_err(|e| anyhow!(e))?;
    let opts = AnalysisOptions {
        seed: args.get_parse("seed", 0xCAFEu64).map_err(|e| anyhow!(e))?,
        max_tiles_per_layer: args.get_parse("tiles", 16usize).map_err(|e| anyhow!(e))?,
        ..Default::default()
    };
    let name = args.get_or("net", "resnet50");
    let net = Network::by_name(name).ok_or_else(|| anyhow!("unknown network '{name}'"))?;
    use sa_lowpower::workload::{gen_feature_map, prune_weights, LayerKind};

    // representative conv layers (skip stem, dw, fc)
    let picks: Vec<usize> = net
        .layers
        .iter()
        .enumerate()
        .filter(|(i, l)| *i > 0 && l.kind == LayerKind::Conv)
        .map(|(i, _)| i)
        .step_by(7)
        .collect();

    // The paper set plus the weight-gating extension stack (a composed
    // spec the closed legacy struct also expressed as weight_zvcg=true),
    // routed through one engine instance.
    let engine = SaEngine::builder()
        .options(opts)
        .configs(ConfigSet::paper().with(
            "proposed+w-zvcg",
            CodingStack::parse("w:zvcg+bic-mantissa,i:zvcg").map_err(|e| anyhow!(e))?,
        ))
        .threads(1)
        .build()?;

    println!("== Pruning extension (paper §III-B future work) on {name} ==");
    let mut t = Table::new([
        "prune_%",
        "weight_zeros_%",
        "proposed_savings_%",
        "proposed+w-zvcg_savings_%",
    ]);
    for prune in [0.0f64, 0.2, 0.4, 0.6, 0.8] {
        let (mut base, mut prop, mut propw) = (0.0, 0.0, 0.0);
        let mut wz = 0.0;
        for &i in &picks {
            let layer = &net.layers[i];
            let seed = engine.options().seed;
            let fm = gen_feature_map(layer, seed, i);
            let mut w = gen_weights(layer, seed, i);
            prune_weights(&mut w, prune);
            wz += w.iter().filter(|&&v| v == 0.0).count() as f64 / w.len() as f64;
            let rep = engine.analyze_layer_with_data(layer, i, fm, w)?;
            base += rep.energy_of("baseline").unwrap().total();
            prop += rep.energy_of("proposed").unwrap().total();
            propw += rep.energy_of("proposed+w-zvcg").unwrap().total();
        }
        t.row([
            format!("{:.0}", prune * 100.0),
            format!("{:.1}", 100.0 * wz / picks.len() as f64),
            format!("{:.2}", 100.0 * (base - prop) / base),
            format!("{:.2}", 100.0 * (base - propw) / base),
        ]);
    }
    t.print();
    println!(
        "\nweight-side ZVCG is dead weight at 0 % pruning but compounds with\n\
         the proposed design as pruning raises weight sparsity."
    );
    Ok(())
}

/// Extension: savings and area overhead vs. SA size (the paper's scaling
/// argument, §IV).
fn sweep_size(args: &Args) -> Result<()> {
    args.validate(&["seed", "tiles"]).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 0xCAFEu64).map_err(|e| anyhow!(e))?;
    let tiles = args.get_parse("tiles", 8usize).map_err(|e| anyhow!(e))?;
    let net = Network::by_name("resnet50").unwrap();
    // a spread of layers across the network
    let picks: Vec<usize> = (1..net.layers.len() - 1).step_by(9).collect();

    println!("== SA size sweep: savings & overhead vs array dimension ==");
    let mut t = Table::new([
        "array",
        "power_savings_%",
        "area_overhead_%",
    ]);
    for dim in [4usize, 8, 16, 32, 64] {
        // One engine per geometry: the SA dimensions live in the options.
        let engine = SaEngine::builder()
            .seed(seed)
            .max_tiles_per_layer(tiles)
            .sa(SaConfig { rows: dim, cols: dim, ..SaConfig::default() })
            .configs(ConfigSet::paper())
            .threads(1)
            .build()?;
        let (mut base, mut prop) = (0.0, 0.0);
        for &i in &picks {
            let rep = engine.analyze_layer(&net.layers[i], i)?;
            base += rep.energy_of("baseline").unwrap().total();
            prop += rep.energy_of("proposed").unwrap().total();
        }
        let area = AreaModel::default()
            .area(dim, dim, &ConfigRegistry::lookup("proposed").unwrap().stack())
            .overhead_pct();
        t.row([
            format!("{dim}x{dim}"),
            format!("{:.2}", 100.0 * (base - prop) / base),
            format!("{area:.2}"),
        ]);
    }
    t.print();
    println!("\nsavings hold across sizes while the overhead shrinks (paper §IV).");
    Ok(())
}

/// Extension: the transformer workload (attention + MLP GEMMs) swept
/// under both dataflows — the scenario-diversity axis of the ROADMAP
/// (dataflow choice shifts which streams dominate switching activity).
fn transformer(args: &Args) -> Result<()> {
    args.validate(&[
        "tiles", "threads", "seed", "csv-dir", "json-dir", "dw-channels", "backend",
        "coding", "no-specialize",
    ])
    .map_err(|e| anyhow!(e))?;
    let net = Network::by_name("transformer").unwrap();
    let mut t = Table::new([
        "dataflow",
        "baseline_nJ",
        "proposed_nJ",
        "savings_%",
        "streaming_cut_%",
    ]);
    for df in Dataflow::ALL {
        let engine = SaEngine::builder()
            .options(opts_from(args)?)
            .dataflow(*df)
            .configs(configs_from(args, ConfigSet::paper())?)
            .backend(backend_from(args)?)
            .threads(threads_from(args)?)
            .build()?;
        let sweep = engine.sweep(&net)?;
        t.row([
            df.long_name().to_string(),
            format!("{:.3}", sweep.total_energy("baseline") * 1e-6),
            format!("{:.3}", sweep.total_energy("proposed") * 1e-6),
            format!("{:.2}", sweep.overall_savings_pct("baseline", "proposed")),
            format!(
                "{:.2}",
                sweep.streaming_activity_reduction_pct("baseline", "proposed")
            ),
        ]);
        maybe_json(args, &format!("transformer_{}", df.name()), &sweep)?;
    }
    println!(
        "== Transformer workload ({} layers: QK^T / AV / projections / FFN) ==",
        net.layers.len()
    );
    t.print();
    println!(
        "\ndense attention operands gate far less than ReLU CNN streams, so the\n\
         proposed coding leans on BIC here; the OS dataflow registers each\n\
         stream word once per lane instead of once per PE."
    );
    maybe_csv(args, "transformer_dataflows", &t)?;
    Ok(())
}

fn e2e(args: &Args) -> Result<()> {
    args.validate(&["requests", "artifacts", "seed", "tiles"])
        .map_err(|e| anyhow!(e))?;
    let n_req = args.get_parse("requests", 4usize).map_err(|e| anyhow!(e))?;
    let seed = args.get_parse("seed", 7u64).map_err(|e| anyhow!(e))?;
    let dir = args.get_or("artifacts", "artifacts");
    let engine = SaEngine::builder()
        .seed(seed)
        .max_tiles_per_layer(args.get_parse("tiles", 16usize).map_err(|e| anyhow!(e))?)
        .configs(ConfigSet::paper())
        .build()?;

    println!("== e2e: XLA inference (AOT artifacts) + SA power analysis ==");
    let params = TinycnnParams::generate(seed);
    let server = InferenceServer::start(std::path::Path::new(dir), params.clone())?;
    let net = server.network.clone();

    let mut total_base = 0.0;
    let mut total_prop = 0.0;
    for r in 0..n_req {
        let image = synthetic_image(seed ^ r as u64);
        let resp = server.infer(image.clone())?;
        print!(
            "req {r}: latency {:?}, logits[0..3] = {:?}, zeros/layer = [",
            resp.latency,
            &resp.logits[..3.min(resp.logits.len())]
        );
        for z in &resp.zero_fractions {
            print!("{:.0}% ", z * 100.0);
        }
        println!("]");
        // SA power on the *real* activations of this request: one
        // streaming job per layer, fanned over the engine's pool.
        let mut fm = image;
        let mut handles = Vec::new();
        for (i, layer) in net.layers.iter().enumerate() {
            if i >= resp.activations.len() {
                break; // fc head: skip in per-request power detail
            }
            handles.push(engine.submit(LayerJob::with_data(
                layer.clone(),
                i,
                fm.clone(),
                params.gemm_weights(i).to_vec(),
            ))?);
            fm = resp.activations[i].clone();
        }
        for h in handles {
            let rep = h.wait()?;
            total_base += rep.energy_of("baseline").unwrap().total();
            total_prop += rep.energy_of("proposed").unwrap().total();
        }
    }
    println!(
        "\nSA energy over {n_req} requests: baseline {:.3} nJ, proposed {:.3} nJ ({:.1} % saved)",
        total_base * 1e-6,
        total_prop * 1e-6,
        100.0 * (total_base - total_prop) / total_base
    );
    println!(
        "served {} requests, mean latency {:?}, max {:?}",
        server.metrics.requests(),
        server.metrics.mean_latency(),
        server.metrics.max_latency()
    );
    Ok(())
}

/// `serve`: sweep-as-a-service. Line-delimited job specs on stdin, one
/// compact v3 report JSON line per job on stdout (tagged with its input
/// line number; up to `--jobs` overlapped at a time); job failures
/// become per-line error records instead of process exit. All jobs
/// share one content-addressed result store, so repeated shapes are
/// priced once. See `engine::serve` and README "Running as a service".
fn serve(args: &Args) -> Result<()> {
    args.validate(&[
        "threads", "jobs", "engine-cap", "cache", "cache-budget", "cache-dir",
        "summary-json",
    ])
    .map_err(|e| anyhow!(e))?;
    let threads = args.get_parse("threads", 2usize).map_err(|e| anyhow!(e))?;
    let jobs = args.get_parse("jobs", 1usize).map_err(|e| anyhow!(e))?;
    if jobs == 0 {
        bail!("--jobs must be >= 1");
    }
    let engine_cap = args
        .get_parse("engine-cap", DEFAULT_ENGINE_CAP)
        .map_err(|e| anyhow!(e))?;
    if engine_cap == 0 {
        bail!("--engine-cap must be >= 1");
    }
    let budget =
        args.get_parse("cache-budget", 64usize << 20).map_err(|e| anyhow!(e))?;
    let cache = match args.get_or("cache", "memory") {
        "off" => CachePolicy::Off,
        "memory" => CachePolicy::Memory { budget },
        "persistent" => CachePolicy::Persistent {
            budget,
            dir: args.get_or("cache-dir", ".sa-lowpower-cache").into(),
        },
        other => bail!("--cache must be off|memory|persistent, got '{other}'"),
    };
    let opts = ServeOptions { threads, jobs, engine_cap, cache };
    // Summary and diagnostics go to stderr: stdout carries only report /
    // error-record lines so the output stays machine-consumable. The
    // writer is handed to a gather thread inside the loop, so it must be
    // the Send-able handle, not a StdoutLock.
    let summary = serve_loop(std::io::stdin().lock(), std::io::stdout(), &opts)?;
    let cache_note = match summary.cache {
        Some(c) => {
            let lost = if c.persist_failures > 0 {
                format!(", {} persist failures", c.persist_failures)
            } else {
                String::new()
            };
            format!(
                "; cache: {} hits, {} misses, {} evictions, {} entries, {} bytes{lost}",
                c.hits, c.misses, c.evictions, c.entries, c.bytes
            )
        }
        None => String::new(),
    };
    eprintln!(
        "serve: {} jobs, {} completed, {} delivered, {} failed; engines: {} built, {} evicted{cache_note}",
        summary.jobs,
        summary.completed,
        summary.delivered,
        summary.failed,
        summary.engines_built,
        summary.engines_evicted
    );
    eprintln!("serve: latency  {}", summary.latency.render());
    eprintln!("serve: hit-rate {}", summary.hit_rate.render());
    if let Some(path) = args.get("summary-json") {
        std::fs::write(path, summary.to_json_value().render())
            .map_err(|e| anyhow!("--summary-json '{path}': {e}"))?;
    }
    Ok(())
}

//! Synthetic workload data (the DESIGN.md §2 substitution for trained
//! weights and ImageNet activations).
//!
//! * **Weights**: He-style fan-in-scaled Gaussians clipped to [-1, 1].
//!   This reproduces the two distributional facts the paper's Fig. 2
//!   exploits: bf16 exponents concentrated just below the bias, mantissas
//!   near-uniform (asserted by `stats` tests and the Fig. 2 bench).
//! * **Activations**: post-ReLU statistics — a per-layer zero fraction
//!   plus half-normal magnitudes for the non-zeros. The first layer of a
//!   network sees image-like (dense, positive) values instead.
//!
//! Everything is seeded per (network, layer) so figures regenerate
//! bit-identically and are independent of evaluation order.

use crate::util::Rng64;

use super::layer::{Layer, LayerKind};

/// Deterministic per-layer RNG: seed ⊕ layer index.
pub fn layer_rng(seed: u64, layer_idx: usize) -> Rng64 {
    Rng64::new(seed ^ (layer_idx as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93))
}

/// Synthetic per-layer zero fraction of the *input* activations.
///
/// The paper (Figs. 4–5) measures 10–80 % zeros depending on the layer,
/// with deeper layers typically sparser. We model that with a
/// deterministic per-layer draw in [0.35, 0.80] for ReLU-fed layers and
/// ~0 for image-fed layers.
pub fn layer_zero_fraction(layer: &Layer, seed: u64, layer_idx: usize) -> f64 {
    if !layer.relu_input {
        // Image-fed stem: normalized ImageNet pixels contain a small
        // fraction of exact zeros (saturated black regions); the paper's
        // Figs. 4–5 likewise show a small non-zero percentage at layer 1.
        return 0.08;
    }
    let mut r = layer_rng(seed ^ 0x5A5A, layer_idx);
    0.35 + 0.45 * r.uniform()
}

/// Generate the layer's weight tensor in GEMM layout (K×N row-major,
/// K = kh·kw·cin): fan-in-scaled Gaussian, clipped to [-1, 1] (the
/// paper notes trained weights are bounded to this range).
pub fn gen_weights(layer: &Layer, seed: u64, layer_idx: usize) -> Vec<f32> {
    let mut r = layer_rng(seed ^ 0x57E1, layer_idx);
    let g = layer.gemm();
    let std = (2.0 / layer.fan_in() as f64).sqrt();
    let count = match layer.kind {
        LayerKind::Depthwise => g.k * layer.cin, // per-channel K×1 columns
        _ => g.k * g.n,
    };
    (0..count)
        .map(|_| (r.normal_ms(0.0, std)).clamp(-1.0, 1.0) as f32)
        .collect()
}

/// Magnitude-prune a weight tensor: zero the `frac` smallest |w| values
/// (the paper's future-work lever: "the abundance of zeros can be
/// artificially increased in the weights by enabling weight pruning").
pub fn prune_weights(weights: &mut [f32], frac: f64) {
    assert!((0.0..=1.0).contains(&frac));
    let cut = ((weights.len() as f64) * frac) as usize;
    if cut == 0 {
        return;
    }
    let mut mags: Vec<f32> = weights.iter().map(|w| w.abs()).collect();
    mags.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let threshold = mags[cut - 1];
    let mut zeroed = 0usize;
    for w in weights.iter_mut() {
        if w.abs() <= threshold && zeroed < cut {
            *w = 0.0;
            zeroed += 1;
        }
    }
}

/// Generate a single-image NHWC feature map for the layer's input:
/// image-like for the stem, post-ReLU-like elsewhere.
pub fn gen_feature_map(layer: &Layer, seed: u64, layer_idx: usize) -> Vec<f32> {
    let mut r = layer_rng(seed ^ 0xFEED, layer_idx);
    let zf = layer_zero_fraction(layer, seed, layer_idx);
    let count = layer.h * layer.w * layer.cin;
    (0..count)
        .map(|_| {
            if layer.relu_input {
                if r.chance(zf) {
                    0.0
                } else {
                    // half-normal magnitudes, like ReLU(N(0, σ))
                    (r.normal().abs() * 0.5) as f32
                }
            } else if r.chance(zf) {
                // saturated black pixels normalize to exactly zero
                0.0
            } else {
                // normalized image pixels: roughly N(0,1) clipped
                (r.normal().clamp(-2.5, 2.5)) as f32
            }
        })
        .collect()
}

/// Measured zero fraction of a feature map (sanity/reporting).
pub fn zero_fraction(values: &[f32]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().filter(|&&v| v == 0.0).count() as f64 / values.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::resnet50;

    #[test]
    fn deterministic_per_layer() {
        let net = resnet50();
        let w1 = gen_weights(&net.layers[3], 7, 3);
        let w2 = gen_weights(&net.layers[3], 7, 3);
        assert_eq!(w1, w2);
        let w3 = gen_weights(&net.layers[3], 8, 3);
        assert_ne!(w1, w3);
    }

    #[test]
    fn weights_bounded_and_scaled() {
        let net = resnet50();
        let l = &net.layers[5];
        let w = gen_weights(l, 42, 5);
        assert_eq!(w.len(), l.gemm().k * l.gemm().n);
        assert!(w.iter().all(|v| (-1.0..=1.0).contains(v)));
        let std = (w.iter().map(|&v| (v as f64).powi(2)).sum::<f64>()
            / w.len() as f64)
            .sqrt();
        let want = (2.0 / l.fan_in() as f64).sqrt();
        assert!((std - want).abs() / want < 0.1, "std {std} vs {want}");
    }

    #[test]
    fn feature_map_zero_fraction_matches_model() {
        let net = resnet50();
        let l = &net.layers[10];
        let fm = gen_feature_map(l, 11, 10);
        let want = layer_zero_fraction(l, 11, 10);
        let got = zero_fraction(&fm);
        assert!((got - want).abs() < 0.03, "{got} vs {want}");
        assert!(fm.iter().all(|&v| v >= 0.0), "ReLU outputs nonneg");
    }

    #[test]
    fn prune_weights_zeros_exact_fraction() {
        let net = resnet50();
        let mut w = gen_weights(&net.layers[5], 1, 5);
        let n = w.len();
        prune_weights(&mut w, 0.6);
        let zeros = w.iter().filter(|&&v| v == 0.0).count();
        assert!((zeros as f64 / n as f64 - 0.6).abs() < 0.01, "{zeros}/{n}");
        // survivors are the largest magnitudes
        let max_zeroed = 0.0f32; // all zeroed entries are exactly 0 now
        let min_kept = w
            .iter()
            .filter(|&&v| v != 0.0)
            .map(|v| v.abs())
            .fold(f32::MAX, f32::min);
        assert!(min_kept > max_zeroed);
    }

    #[test]
    fn prune_zero_frac_is_noop() {
        let net = resnet50();
        let mut w = gen_weights(&net.layers[5], 1, 5);
        let orig = w.clone();
        prune_weights(&mut w, 0.0);
        assert_eq!(w, orig);
    }

    #[test]
    fn stem_input_is_nearly_dense() {
        let net = resnet50();
        let fm = gen_feature_map(&net.layers[0], 1, 0);
        let z = zero_fraction(&fm);
        assert!((0.04..0.13).contains(&z), "stem zeros {z}");
        assert_eq!(layer_zero_fraction(&net.layers[0], 1, 0), 0.08);
    }

    #[test]
    fn zero_fraction_range_is_papers() {
        let net = resnet50();
        for (i, l) in net.layers.iter().enumerate().skip(1) {
            let z = layer_zero_fraction(l, 100, i);
            assert!((0.35..=0.80).contains(&z), "layer {i}: {z}");
        }
    }
}

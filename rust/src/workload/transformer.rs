//! Transformer encoder workload: the attention + MLP GEMMs of a compact
//! ViT/BERT-style block stack, lowered straight to [`LayerKind::Gemm`]
//! layers (no im2col — these layers *are* matmuls).
//!
//! Per block, the GEMMs an SA compiler would schedule:
//!
//! * `qkv`   — fused Q/K/V projection, `seq × d_model × 3·d_model`;
//! * `attn.qk` — the score matmul `Q·K^T`, `seq × head_dim × seq`
//!   (modelled at single-head granularity: every head runs the same
//!   shape, so one instance is the per-head power sample);
//! * `attn.av` — the value matmul `softmax(S)·V`, `seq × seq × head_dim`;
//! * `attn.proj` — output projection, `seq × d_model × d_model`;
//! * `ffn.up` / `ffn.down` — the MLP pair, `seq × d_model × 4·d_model`
//!   and back.
//!
//! Distribution realism rides on the same substitution machinery as the
//! CNNs (DESIGN.md §2): weights are fan-in-scaled Gaussians (bf16
//! exponents concentrated, mantissas near-uniform — the Fig. 2 facts BIC
//! exploits), and the A-matrix statistics follow `relu_input`:
//! LayerNorm-fed projections and attention operands are **dense signed**
//! streams (`relu_input = false`, ~8 % exact zeros), while the FFN
//! down-projection consumes a **zero-rich post-activation** stream
//! (`relu_input = true`, 35–80 % zeros). That contrast is the point of
//! the workload: transformers feed the array far fewer zeros than ReLU
//! CNNs, so ZVCG has less to gate and the coding/dataflow choice shifts
//! which stream dominates — exactly the scenario diversity the dataflow
//! axis exists to measure.

use super::layer::{Layer, Network};

/// Sequence length (tokens per forward pass).
pub const TRANSFORMER_SEQ: usize = 64;
/// Model width.
pub const TRANSFORMER_D_MODEL: usize = 256;
/// Attention heads (head_dim = d_model / heads).
pub const TRANSFORMER_HEADS: usize = 4;
/// MLP expansion factor.
pub const TRANSFORMER_FFN_MULT: usize = 4;
/// Encoder blocks.
pub const TRANSFORMER_BLOCKS: usize = 2;
/// Classifier width of the final head.
pub const TRANSFORMER_CLASSES: usize = 1000;

/// Build the transformer encoder workload (`Network::by_name("transformer")`).
pub fn transformer() -> Network {
    let (seq, d) = (TRANSFORMER_SEQ, TRANSFORMER_D_MODEL);
    let head_dim = d / TRANSFORMER_HEADS;
    let ffn = TRANSFORMER_FFN_MULT * d;
    let mut layers = Vec::new();
    for b in 1..=TRANSFORMER_BLOCKS {
        let l = |suffix: &str| format!("blk{b}.{suffix}");
        layers.push(Layer::gemm_layer(&l("qkv"), seq, d, 3 * d, false));
        layers.push(Layer::gemm_layer(&l("attn.qk"), seq, head_dim, seq, false));
        layers.push(Layer::gemm_layer(&l("attn.av"), seq, seq, head_dim, false));
        layers.push(Layer::gemm_layer(&l("attn.proj"), seq, d, d, false));
        layers.push(Layer::gemm_layer(&l("ffn.up"), seq, d, ffn, false));
        // the only zero-rich stream: GELU/ReLU output feeding the
        // down-projection
        layers.push(Layer::gemm_layer(&l("ffn.down"), seq, ffn, d, true));
    }
    layers.push(Layer::dense("head", d, TRANSFORMER_CLASSES));
    Network { name: "transformer".into(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{gen_feature_map, gen_weights, GemmShape, LayerKind};

    #[test]
    fn block_structure_and_shapes() {
        let net = transformer();
        assert_eq!(net.layers.len(), 6 * TRANSFORMER_BLOCKS + 1);
        let qk = net.layers.iter().find(|l| l.name == "blk1.attn.qk").unwrap();
        assert_eq!(qk.gemm(), GemmShape { m: 64, k: 64, n: 64 });
        let av = net.layers.iter().find(|l| l.name == "blk2.attn.av").unwrap();
        assert_eq!(av.gemm(), GemmShape { m: 64, k: 64, n: 64 });
        let up = net.layers.iter().find(|l| l.name == "blk1.ffn.up").unwrap();
        assert_eq!(up.gemm(), GemmShape { m: 64, k: 256, n: 1024 });
        let down = net.layers.iter().find(|l| l.name == "blk1.ffn.down").unwrap();
        assert_eq!(down.gemm(), GemmShape { m: 64, k: 1024, n: 256 });
        assert!(down.relu_input, "FFN down-projection input is post-activation");
        assert!(!up.relu_input, "FFN up-projection input is LayerNorm output");
        assert!(net.total_macs() > 0);
    }

    #[test]
    fn registered_by_name() {
        let net = Network::by_name("transformer").unwrap();
        assert_eq!(net.name, "transformer");
        assert!(net
            .layers
            .iter()
            .take(net.layers.len() - 1)
            .all(|l| l.kind == LayerKind::Gemm));
    }

    #[test]
    fn generators_produce_gemm_shaped_tensors() {
        let net = transformer();
        for (i, l) in net.layers.iter().enumerate() {
            let g = l.gemm();
            let fm = gen_feature_map(l, 7, i);
            let w = gen_weights(l, 7, i);
            // Dense head keeps its M=1 convention; Gemm layers carry the
            // full M×K A matrix.
            assert_eq!(fm.len(), g.m * g.k * l.gemm_count(), "layer {}", l.name);
            assert_eq!(w.len(), g.k * g.n * l.gemm_count(), "layer {}", l.name);
        }
    }

    #[test]
    fn attention_streams_are_dense_ffn_down_is_sparse() {
        let net = transformer();
        let zf = |name: &str| {
            let (i, l) = net
                .layers
                .iter()
                .enumerate()
                .find(|(_, l)| l.name == name)
                .unwrap();
            crate::workload::zero_fraction(&gen_feature_map(l, 0xCAFE, i))
        };
        assert!(zf("blk1.attn.qk") < 0.15, "attention operands are dense");
        assert!(zf("blk1.ffn.down") > 0.3, "post-activation stream is zero-rich");
    }
}

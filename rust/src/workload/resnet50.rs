//! ResNet50 layer table (He et al., CVPR 2016; v1.5 stride placement),
//! ImageNet 224×224 input — the paper's first evaluation workload.
//!
//! 53 convolutions (including the downsample projections) + the final
//! fully-connected layer. Spatial sizes follow conv1 (112) → maxpool
//! (56) → stages at 56/28/14/7.

use super::layer::{Layer, Network};

/// Bottleneck stage description: (blocks, mid channels, out channels,
/// input spatial size, first-block stride).
const STAGES: [(usize, usize, usize, usize, usize); 4] = [
    (3, 64, 256, 56, 1),
    (4, 128, 512, 56, 2),
    (6, 256, 1024, 28, 2),
    (3, 512, 2048, 14, 2),
];

/// Build the full ResNet50 layer list.
pub fn resnet50() -> Network {
    let mut layers = Vec::new();
    // conv1: 7×7/2, 3→64, on the raw image (not ReLU input).
    layers.push(Layer::conv("conv1", 7, 3, 64, 2, 224, false));

    let mut cin = 64; // after maxpool, 56×56×64
    for (si, &(blocks, mid, cout, in_h, stride1)) in STAGES.iter().enumerate() {
        let stage = si + 2; // conv2_x .. conv5_x
        let mut h = in_h;
        for b in 0..blocks {
            let stride = if b == 0 { stride1 } else { 1 };
            let prefix = format!("conv{stage}_{}", b + 1);
            // v1.5: stride lives in the 3×3 middle conv.
            layers.push(Layer::conv(&format!("{prefix}a"), 1, cin, mid, 1, h, true));
            layers.push(Layer::conv(
                &format!("{prefix}b"),
                3,
                mid,
                mid,
                stride,
                h,
                true,
            ));
            let out_h = h.div_ceil(stride);
            layers.push(Layer::conv(
                &format!("{prefix}c"),
                1,
                mid,
                cout,
                1,
                out_h,
                true,
            ));
            if b == 0 {
                // projection shortcut
                layers.push(Layer::conv(
                    &format!("{prefix}p"),
                    1,
                    cin,
                    cout,
                    stride,
                    h,
                    true,
                ));
            }
            cin = cout;
            h = out_h;
        }
    }
    layers.push(Layer::dense("fc", 2048, 1000));
    Network { name: "resnet50".into(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LayerKind;

    #[test]
    fn layer_count_matches_architecture() {
        let net = resnet50();
        // 1 stem + Σ blocks(3 convs) + 4 projections + 1 fc
        let convs = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Conv)
            .count();
        assert_eq!(convs, 1 + (3 + 4 + 6 + 3) * 3 + 4); // = 53
        assert_eq!(net.layers.len(), 54);
    }

    #[test]
    fn param_count_close_to_reference() {
        // torchvision resnet50 has ~25.6M params; conv+fc (no BN/bias)
        // is ~25.5M.
        let p = resnet50().total_params();
        assert!(
            (24_000_000..27_000_000).contains(&p),
            "params {p}"
        );
    }

    #[test]
    fn mac_count_close_to_reference() {
        // ~4.1 GMACs at 224×224.
        let m = resnet50().total_macs();
        assert!(
            (3_600_000_000..4_600_000_000).contains(&m),
            "macs {m}"
        );
    }

    #[test]
    fn spatial_chain_is_consistent() {
        let net = resnet50();
        // conv2_1a expects 56×56 input, conv5 last block 7×7 output
        let c21a = net.layers.iter().find(|l| l.name == "conv2_1a").unwrap();
        assert_eq!(c21a.h, 56);
        let c53c = net.layers.iter().find(|l| l.name == "conv5_3c").unwrap();
        assert_eq!(c53c.h, 7);
        assert_eq!(c53c.cout, 2048);
    }

    #[test]
    fn first_layer_is_not_relu_fed() {
        let net = resnet50();
        assert!(!net.layers[0].relu_input);
        assert!(net.layers[1].relu_input);
    }
}

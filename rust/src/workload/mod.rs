//! DNN workloads: layer tables, synthetic data generation, im2col
//! lowering and GEMM tiling.
//!
//! The paper evaluates complete ResNet50 and MobileNet inference
//! (ImageNet resolution, Bfloat16). The real trained weights and test
//! images are substituted per DESIGN.md §2: fan-in-scaled synthetic
//! weights (which reproduce the Fig. 2 exponent/mantissa distributions)
//! and post-ReLU-statistics synthetic activations with per-layer zero
//! fractions. Every layer of both networks is lowered to GEMM exactly as
//! a real SA compiler would (im2col), then tiled to the 16×16 array.
//!
//! Beyond the paper's CNNs, [`transformer`] adds an attention + MLP
//! workload (bare [`LayerKind::Gemm`] layers — QK^T, AV, projections,
//! FFN) whose dense operand streams probe the coding/dataflow space from
//! the opposite end of the sparsity spectrum.

mod generator;
mod im2col;
mod layer;
mod mobilenet;
mod resnet50;
mod tiler;
mod tinycnn;
mod transformer;

pub use generator::*;
pub use im2col::*;
pub use layer::*;
pub use mobilenet::*;
pub use resnet50::*;
pub use tiler::*;
pub use tinycnn::*;
pub use transformer::*;

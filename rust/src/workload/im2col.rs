//! im2col lowering: NHWC feature map → GEMM A-matrix.
//!
//! Patch features are ordered (kh, kw, c) — bit-for-bit the same layout
//! as `python/compile/model.py::im2col` (pytest pins the python side;
//! `rust/tests/integration_runtime.rs` pins the cross-language
//! agreement through the XLA artifacts).

/// SAME-padding amounts (top/left biased like XLA): returns
/// (pad_begin, pad_end) for one spatial dim.
pub fn same_padding(size: usize, k: usize, stride: usize) -> (usize, usize) {
    let out = size.div_ceil(stride);
    let needed = ((out - 1) * stride + k).saturating_sub(size);
    (needed / 2, needed - needed / 2)
}

/// Lower one single-image NHWC feature map (h×w×c, row-major) to the
/// im2col matrix (M×K, M = oh·ow, K = kh·kw·c) under SAME padding.
pub fn im2col_same(
    fm: &[f32],
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) -> Vec<f32> {
    let mut out = Vec::new();
    im2col_same_into(&mut out, fm, h, w, c, kh, kw, stride);
    out
}

/// [`im2col_same`] into a caller-owned buffer (cleared and refilled;
/// capacity reused), for per-thread lowering loops that would otherwise
/// reallocate one patch matrix per layer/channel.
pub fn im2col_same_into(
    out: &mut Vec<f32>,
    fm: &[f32],
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
) {
    assert_eq!(fm.len(), h * w * c, "feature map shape");
    let (ph, _) = same_padding(h, kh, stride);
    let (pw, _) = same_padding(w, kw, stride);
    let oh = h.div_ceil(stride);
    let ow = w.div_ceil(stride);
    let kdim = kh * kw * c;
    out.clear();
    out.resize(oh * ow * kdim, 0f32);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = &mut out[(oy * ow + ox) * kdim..(oy * ow + ox + 1) * kdim];
            for ky in 0..kh {
                let iy = (oy * stride + ky) as isize - ph as isize;
                for kx in 0..kw {
                    let ix = (ox * stride + kx) as isize - pw as isize;
                    let dst = &mut row[(ky * kw + kx) * c..(ky * kw + kx + 1) * c];
                    if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                        let src =
                            &fm[(iy as usize * w + ix as usize) * c..][..c];
                        dst.copy_from_slice(src);
                    }
                    // else: stays zero (padding)
                }
            }
        }
    }
}

/// Extract channel `ch` of an NHWC feature map as a single-channel map
/// (for depthwise lowering).
pub fn extract_channel(fm: &[f32], h: usize, w: usize, c: usize, ch: usize) -> Vec<f32> {
    assert!(ch < c);
    (0..h * w).map(|p| fm[p * c + ch]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_padding_matches_xla_convention() {
        assert_eq!(same_padding(32, 3, 1), (1, 1));
        assert_eq!(same_padding(32, 3, 2), (0, 1));
        assert_eq!(same_padding(224, 7, 2), (2, 3));
        assert_eq!(same_padding(5, 1, 1), (0, 0));
    }

    #[test]
    fn ordering_matches_python_side() {
        // Mirror of python/tests/test_model.py::test_im2col_ordering:
        // 1×2×2×2 input, 2×2 kernel VALID-equivalent (SAME with even k
        // pads at the end; centre patch picks the raw values in order).
        let fm: Vec<f32> = (0..8).map(|x| x as f32).collect(); // 2x2x2
        let a = im2col_same(&fm, 2, 2, 2, 2, 2, 1);
        // oh=ow=2; patch (0,0) covers the full map with no padding:
        // ordered (kh,kw,c) = 0,1,2,...,7
        assert_eq!(&a[0..8], &[0., 1., 2., 3., 4., 5., 6., 7.]);
    }

    #[test]
    fn identity_conv_1x1() {
        // 1×1 conv im2col is the feature map itself, row-major.
        let fm: Vec<f32> = (0..3 * 3 * 4).map(|x| x as f32 * 0.5).collect();
        let a = im2col_same(&fm, 3, 3, 4, 1, 1, 1);
        assert_eq!(a, fm);
    }

    #[test]
    fn stride_two_shape() {
        let fm = vec![1f32; 8 * 8 * 2];
        let a = im2col_same(&fm, 8, 8, 2, 3, 3, 2);
        assert_eq!(a.len(), 4 * 4 * 9 * 2);
    }

    #[test]
    fn padding_contributes_zeros() {
        let fm = vec![1f32; 4 * 4];
        let a = im2col_same(&fm, 4, 4, 1, 3, 3, 1);
        // corner patch (0,0): top row + left col of the 3x3 window are pad
        let first = &a[0..9];
        assert_eq!(first, &[0., 0., 0., 0., 1., 1., 0., 1., 1.]);
    }

    #[test]
    fn into_variant_reuses_buffer_and_matches() {
        let mut buf = vec![9.0f32; 4]; // dirty buffer: must be fully overwritten
        let fm1 = vec![1f32; 4 * 4];
        im2col_same_into(&mut buf, &fm1, 4, 4, 1, 3, 3, 1);
        assert_eq!(buf, im2col_same(&fm1, 4, 4, 1, 3, 3, 1));
        // second, smaller problem into the same (now larger) buffer
        let fm2: Vec<f32> = (0..2 * 2 * 2).map(|x| x as f32).collect();
        im2col_same_into(&mut buf, &fm2, 2, 2, 2, 2, 2, 1);
        assert_eq!(buf, im2col_same(&fm2, 2, 2, 2, 2, 2, 1));
    }

    #[test]
    fn extract_channel_works() {
        let fm: Vec<f32> = (0..2 * 2 * 3).map(|x| x as f32).collect();
        let c1 = extract_channel(&fm, 2, 2, 3, 1);
        assert_eq!(c1, vec![1., 4., 7., 10.]);
    }
}

//! TinyConvNet: the e2e demo workload, mirrored layer-for-layer from
//! `python/compile/model.py` (the AOT artifact `tinycnn_forward`).

use super::layer::{Layer, Network};

/// (kernel, cin, cout, stride, input spatial) — must match
/// `model.TINYCNN_CONVS` in python/compile/model.py.
pub const TINYCNN_CONVS: [(usize, usize, usize, usize, usize); 5] = [
    (3, 3, 16, 1, 32),
    (3, 16, 32, 2, 32),
    (3, 32, 32, 1, 16),
    (3, 32, 64, 2, 16),
    (3, 64, 64, 1, 8),
];

pub const TINYCNN_CLASSES: usize = 10;
pub const TINYCNN_INPUT_HW: usize = 32;
pub const TINYCNN_INPUT_C: usize = 3;

/// Build the TinyConvNet layer list (5 convs + fc head).
pub fn tinycnn() -> Network {
    let mut layers = Vec::new();
    for (i, &(k, cin, cout, s, h)) in TINYCNN_CONVS.iter().enumerate() {
        layers.push(Layer::conv(&format!("conv{}", i + 1), k, cin, cout, s, h, i > 0));
    }
    layers.push(Layer::dense("fc", 64, TINYCNN_CLASSES));
    Network { name: "tinycnn".into(), layers }
}

/// Parameter shapes of the forward artifact, in argument order (conv
/// weights HWIO, then fc weight, then fc bias) — must match
/// `model.tinycnn_param_shapes()`.
pub fn tinycnn_param_shapes() -> Vec<Vec<usize>> {
    let mut shapes: Vec<Vec<usize>> = TINYCNN_CONVS
        .iter()
        .map(|&(k, cin, cout, _, _)| vec![k, k, cin, cout])
        .collect();
    shapes.push(vec![64, TINYCNN_CLASSES]);
    shapes.push(vec![TINYCNN_CLASSES]);
    shapes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spatial_chain() {
        let net = tinycnn();
        assert_eq!(net.layers[0].out_h(), 32);
        assert_eq!(net.layers[1].out_h(), 16);
        assert_eq!(net.layers[3].out_h(), 8);
        assert_eq!(net.layers[4].out_h(), 8);
    }

    #[test]
    fn param_shapes_match_python_side() {
        let shapes = tinycnn_param_shapes();
        assert_eq!(shapes.len(), 7);
        assert_eq!(shapes[0], vec![3, 3, 3, 16]);
        assert_eq!(shapes[4], vec![3, 3, 64, 64]);
        assert_eq!(shapes[5], vec![64, 10]);
        assert_eq!(shapes[6], vec![10]);
    }
}

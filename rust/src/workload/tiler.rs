//! GEMM tiling onto the physical SA, plus the sampled layer analysis
//! used by the figure sweeps.
//!
//! Output-stationary tiling: the M and N dimensions are cut into
//! rows×cols blocks (padded with zeros at the edges — padding rows/cols
//! stream zeros, which the simulators handle like any other value); the
//! K dimension streams through the array unbounded.
//!
//! Full per-layer GEMMs reach billions of MAC slots; like the paper's
//! own 100-image sampling, the sweeps analyze a deterministic sample of
//! tiles per layer and scale, with the sample size configurable
//! (`TilePlan::sample`).

use crate::bf16::Bf16;
use crate::sa::{Tile, TileBuffers};
use crate::util::Rng64;

use super::layer::GemmShape;

/// A GEMM instance in f32 (row-major A: M×K, B: K×N).
#[derive(Clone, Debug)]
pub struct Gemm {
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub shape: GemmShape,
}

impl Gemm {
    pub fn new(a: Vec<f32>, b: Vec<f32>, shape: GemmShape) -> Self {
        assert_eq!(a.len(), shape.m * shape.k);
        assert_eq!(b.len(), shape.k * shape.n);
        Gemm { a, b, shape }
    }
}

/// The tile grid of a GEMM on a rows×cols SA.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TileGrid {
    pub m_tiles: usize,
    pub n_tiles: usize,
    pub rows: usize,
    pub cols: usize,
}

impl TileGrid {
    pub fn of(shape: GemmShape, rows: usize, cols: usize) -> Self {
        TileGrid {
            m_tiles: shape.m.div_ceil(rows),
            n_tiles: shape.n.div_ceil(cols),
            rows,
            cols,
        }
    }

    pub fn total(&self) -> usize {
        self.m_tiles * self.n_tiles
    }
}

/// Extract tile (mi, ni) of a GEMM at *partial occupancy*: edge tiles
/// use only the rows/columns that carry real data (m_eff × k × n_eff).
/// Unused PE rows/columns of the physical array are clock-gated off
/// identically in every design variant, so modelling them would only add
/// an equal constant to both sides — and padding them with zeros instead
/// would let ZVCG "save" power on data that never exists.
pub fn extract_tile(g: &Gemm, grid: &TileGrid, mi: usize, ni: usize) -> Tile {
    extract_tile_into(g, grid, mi, ni, &mut TileBuffers::default())
}

/// [`extract_tile`] with allocation reuse: every buffer of the produced
/// tile comes from `buf` (recover them afterwards with
/// [`Tile::into_buffers`]). The sweep pipeline runs thousands of tiles
/// per layer through one scratch set per worker thread.
pub fn extract_tile_into(
    g: &Gemm,
    grid: &TileGrid,
    mi: usize,
    ni: usize,
    buf: &mut TileBuffers,
) -> Tile {
    assert!(mi < grid.m_tiles && ni < grid.n_tiles);
    let k = g.shape.k;
    let m_eff = grid.rows.min(g.shape.m - mi * grid.rows);
    let n_eff = grid.cols.min(g.shape.n - ni * grid.cols);
    let (mut a, mut b) = buf.take_operands();
    for r in 0..m_eff {
        let src_row = mi * grid.rows + r;
        let src = &g.a[src_row * g.shape.k..src_row * g.shape.k + k];
        a.extend(src.iter().map(|&x| Bf16::from_f32(x)));
    }
    for r in 0..k {
        let row = &g.b[r * g.shape.n..(r + 1) * g.shape.n];
        let src = &row[ni * grid.cols..ni * grid.cols + n_eff];
        b.extend(src.iter().map(|&x| Bf16::from_f32(x)));
    }
    Tile::new_in(buf, a, b, m_eff, k, n_eff)
}

/// Which tiles of a grid to analyze: all, or a deterministic sample.
#[derive(Clone, Debug)]
pub struct TilePlan {
    /// (mi, ni) pairs to run.
    pub picks: Vec<(usize, usize)>,
    /// Scale factor total_tiles / picked_tiles for extrapolating energy.
    pub scale: f64,
}

impl TilePlan {
    /// Every tile, scale 1.
    pub fn exhaustive(grid: &TileGrid) -> Self {
        let picks = (0..grid.m_tiles)
            .flat_map(|mi| (0..grid.n_tiles).map(move |ni| (mi, ni)))
            .collect::<Vec<_>>();
        TilePlan { picks, scale: 1.0 }
    }

    /// A deterministic sample of at most `max_tiles` tiles (without
    /// replacement), scale = total/picked.
    pub fn sample(grid: &TileGrid, max_tiles: usize, seed: u64) -> Self {
        let total = grid.total();
        if total <= max_tiles {
            return Self::exhaustive(grid);
        }
        let mut rng = Rng64::new(seed ^ 0x7117);
        // partial Fisher–Yates over the flattened index space
        let mut indices: Vec<usize> = (0..total).collect();
        for i in 0..max_tiles {
            let j = i + rng.below(total - i);
            indices.swap(i, j);
        }
        let picks = indices[..max_tiles]
            .iter()
            .map(|&f| (f / grid.n_tiles, f % grid.n_tiles))
            .collect();
        TilePlan { picks, scale: total as f64 / max_tiles as f64 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::GemmShape;

    fn small_gemm() -> Gemm {
        let shape = GemmShape { m: 5, k: 3, n: 7 };
        let a: Vec<f32> = (0..15).map(|x| x as f32).collect();
        let b: Vec<f32> = (0..21).map(|x| x as f32 * 0.5).collect();
        Gemm::new(a, b, shape)
    }

    #[test]
    fn grid_covers_with_padding() {
        let g = TileGrid::of(GemmShape { m: 33, k: 10, n: 16 }, 16, 16);
        assert_eq!((g.m_tiles, g.n_tiles), (3, 1));
        assert_eq!(g.total(), 3);
    }

    #[test]
    fn extract_tile_uses_partial_occupancy_at_edges() {
        let g = small_gemm();
        let grid = TileGrid::of(g.shape, 4, 4);
        assert_eq!((grid.m_tiles, grid.n_tiles), (2, 2));
        // interior tile: full occupancy
        let t00 = extract_tile(&g, &grid, 0, 0);
        assert_eq!((t00.m, t00.k, t00.n), (4, 3, 4));
        // edge tile: only the real 1 row × 3 cols
        let t = extract_tile(&g, &grid, 1, 1);
        assert_eq!((t.m, t.k, t.n), (1, 3, 3));
        assert_eq!(t.a_at(0, 0).to_f32(), 12.0);
        assert_eq!(t.b_at(0, 0).to_f32(), 2.0);
    }

    #[test]
    fn tiled_results_reassemble_to_full_gemm() {
        let g = small_gemm();
        let grid = TileGrid::of(g.shape, 4, 4);
        // reference full result
        let a16: Vec<Bf16> = g.a.iter().map(|&x| Bf16::from_f32(x)).collect();
        let b16: Vec<Bf16> = g.b.iter().map(|&x| Bf16::from_f32(x)).collect();
        let full =
            crate::bf16::matmul_f32acc(&a16, &b16, g.shape.m, g.shape.k, g.shape.n);
        let mut seen = 0usize;
        for mi in 0..grid.m_tiles {
            for ni in 0..grid.n_tiles {
                let t = extract_tile(&g, &grid, mi, ni);
                let c = t.reference_result();
                for r in 0..t.m {
                    for cc in 0..t.n {
                        let (gr, gc) = (mi * 4 + r, ni * 4 + cc);
                        let want = full[gr * g.shape.n + gc];
                        assert_eq!(c[r * t.n + cc], want, "({gr},{gc})");
                        seen += 1;
                    }
                }
            }
        }
        // partial-occupancy tiles must still cover every output element
        assert_eq!(seen, g.shape.m * g.shape.n);
    }

    #[test]
    fn sample_is_deterministic_and_in_range() {
        let grid = TileGrid::of(GemmShape { m: 640, k: 8, n: 640 }, 16, 16);
        let p1 = TilePlan::sample(&grid, 10, 99);
        let p2 = TilePlan::sample(&grid, 10, 99);
        assert_eq!(p1.picks, p2.picks);
        assert_eq!(p1.picks.len(), 10);
        assert!((p1.scale - 160.0).abs() < 1e-9);
        for &(mi, ni) in &p1.picks {
            assert!(mi < grid.m_tiles && ni < grid.n_tiles);
        }
        // without replacement
        let mut seen = p1.picks.clone();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 10);
    }

    #[test]
    fn extract_into_matches_fresh_extract() {
        let g = small_gemm();
        let grid = TileGrid::of(g.shape, 4, 4);
        let mut buf = TileBuffers::default();
        for mi in 0..grid.m_tiles {
            for ni in 0..grid.n_tiles {
                let fresh = extract_tile(&g, &grid, mi, ni);
                let reused = extract_tile_into(&g, &grid, mi, ni, &mut buf);
                assert_eq!(fresh, reused, "tile ({mi},{ni})");
                buf = reused.into_buffers();
            }
        }
    }

    #[test]
    fn small_grid_is_exhaustive() {
        let grid = TileGrid::of(GemmShape { m: 20, k: 4, n: 20 }, 16, 16);
        let p = TilePlan::sample(&grid, 100, 1);
        assert_eq!(p.picks.len(), grid.total());
        assert_eq!(p.scale, 1.0);
    }
}

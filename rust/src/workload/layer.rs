//! Layer descriptors and their GEMM lowering (CNN and transformer).

/// Kind of layer, as it maps onto the SA.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    /// Standard convolution (kh×kw×cin per output channel).
    Conv,
    /// Depthwise convolution (one kh×kw filter per channel; lowers to
    /// `cin` independent skinny GEMMs — a known poor fit for SAs).
    Depthwise,
    /// Fully connected (M=1 GEMM).
    Dense,
    /// A bare M×K×N GEMM (no im2col lowering) — transformer attention
    /// and MLP matmuls. The A matrix is the layer's "feature map"
    /// (M×K values); B is the K×N weight/operand matrix.
    Gemm,
}

/// One layer of a CNN, with everything needed to lower it to GEMM.
#[derive(Clone, Debug, PartialEq)]
pub struct Layer {
    /// Human-readable name (matches the x-axis labels of Figs. 4–5).
    pub name: String,
    pub kind: LayerKind,
    /// Kernel height/width (1 for Dense).
    pub kh: usize,
    pub kw: usize,
    /// Input / output channels (for Depthwise, cout == cin).
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    /// Input spatial size (square feature maps; 1 for Dense).
    pub h: usize,
    pub w: usize,
    /// Whether this layer's inputs come from a ReLU (zero-rich) — drives
    /// the synthetic activation generator and matches the paper's
    /// zero-percentage plots.
    pub relu_input: bool,
}

/// GEMM problem dimensions after im2col lowering.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmShape {
    pub m: usize,
    pub k: usize,
    pub n: usize,
}

impl GemmShape {
    pub fn macs(&self) -> u64 {
        (self.m * self.k * self.n) as u64
    }
}

impl Layer {
    pub fn conv(
        name: &str,
        kh: usize,
        cin: usize,
        cout: usize,
        stride: usize,
        h: usize,
        relu_input: bool,
    ) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Conv,
            kh,
            kw: kh,
            cin,
            cout,
            stride,
            h,
            w: h,
            relu_input,
        }
    }

    pub fn depthwise(name: &str, c: usize, stride: usize, h: usize) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Depthwise,
            kh: 3,
            kw: 3,
            cin: c,
            cout: c,
            stride,
            h,
            w: h,
            relu_input: true,
        }
    }

    pub fn dense(name: &str, cin: usize, cout: usize) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Dense,
            kh: 1,
            kw: 1,
            cin,
            cout,
            stride: 1,
            h: 1,
            w: 1,
            relu_input: true,
        }
    }

    /// A bare M×K×N GEMM layer (transformer matmuls). `relu_input`
    /// selects the activation statistics of the A matrix: `true` for
    /// zero-rich post-activation streams (e.g. the FFN down-projection
    /// after GELU/ReLU), `false` for dense signed streams (LayerNorm
    /// outputs, attention scores).
    pub fn gemm_layer(
        name: &str,
        m: usize,
        k: usize,
        n: usize,
        relu_input: bool,
    ) -> Self {
        Layer {
            name: name.into(),
            kind: LayerKind::Gemm,
            kh: 1,
            kw: 1,
            cin: k,
            cout: n,
            stride: 1,
            // spatial fields double as the M extent so the generators'
            // `h·w·cin` A-matrix sizing holds for every kind
            h: m,
            w: 1,
            relu_input,
        }
    }

    /// Output spatial size under SAME padding.
    pub fn out_h(&self) -> usize {
        self.h.div_ceil(self.stride)
    }

    pub fn out_w(&self) -> usize {
        self.w.div_ceil(self.stride)
    }

    /// The GEMM this layer lowers to (per channel for Depthwise).
    pub fn gemm(&self) -> GemmShape {
        match self.kind {
            LayerKind::Conv => GemmShape {
                m: self.out_h() * self.out_w(),
                k: self.kh * self.kw * self.cin,
                n: self.cout,
            },
            LayerKind::Depthwise => GemmShape {
                m: self.out_h() * self.out_w(),
                k: self.kh * self.kw,
                n: 1,
            },
            LayerKind::Dense => GemmShape { m: 1, k: self.cin, n: self.cout },
            LayerKind::Gemm => {
                GemmShape { m: self.h * self.w, k: self.cin, n: self.cout }
            }
        }
    }

    /// Number of independent GEMMs (channels for Depthwise, else 1).
    pub fn gemm_count(&self) -> usize {
        match self.kind {
            LayerKind::Depthwise => self.cin,
            _ => 1,
        }
    }

    /// Total multiply-accumulates of the layer.
    pub fn macs(&self) -> u64 {
        self.gemm().macs() * self.gemm_count() as u64
    }

    /// Weight parameter count.
    pub fn params(&self) -> u64 {
        match self.kind {
            LayerKind::Conv => (self.kh * self.kw * self.cin * self.cout) as u64,
            LayerKind::Depthwise => (self.kh * self.kw * self.cin) as u64,
            LayerKind::Dense | LayerKind::Gemm => (self.cin * self.cout) as u64,
        }
    }

    /// Fan-in (for He-style synthetic weight scaling).
    pub fn fan_in(&self) -> usize {
        match self.kind {
            LayerKind::Depthwise => self.kh * self.kw,
            _ => self.kh * self.kw * self.cin,
        }
    }
}

/// A whole network: named layer list.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: String,
    pub layers: Vec<Layer>,
}

impl Network {
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn total_params(&self) -> u64 {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Registered workload names, in lookup order. Kept next to
    /// [`Network::by_name`] so usage strings derive from code; a test
    /// asserts the two stay in sync.
    pub const NAMES: &'static [&'static str] =
        &["resnet50", "mobilenet", "tinycnn", "transformer"];

    /// `resnet50|mobilenet|...` — for CLI usage strings.
    pub fn name_list() -> String {
        Self::NAMES.join("|")
    }

    pub fn by_name(name: &str) -> Option<Network> {
        match name {
            "resnet50" => Some(super::resnet50()),
            "mobilenet" => Some(super::mobilenet_v1()),
            "tinycnn" => Some(super::tinycnn()),
            "transformer" => Some(super::transformer()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registered_names_all_resolve() {
        for name in Network::NAMES {
            let net = Network::by_name(name).unwrap();
            assert_eq!(&net.name, name);
        }
        assert!(Network::by_name("bogus").is_none());
        assert_eq!(
            Network::name_list(),
            "resnet50|mobilenet|tinycnn|transformer"
        );
    }

    #[test]
    fn conv_gemm_lowering() {
        let l = Layer::conv("c", 3, 64, 128, 2, 56, true);
        let g = l.gemm();
        assert_eq!(g, GemmShape { m: 28 * 28, k: 3 * 3 * 64, n: 128 });
        assert_eq!(l.macs(), (28 * 28 * 576 * 128) as u64);
        assert_eq!(l.gemm_count(), 1);
    }

    #[test]
    fn depthwise_lowering() {
        let l = Layer::depthwise("dw", 256, 1, 14);
        assert_eq!(l.gemm(), GemmShape { m: 196, k: 9, n: 1 });
        assert_eq!(l.gemm_count(), 256);
        assert_eq!(l.fan_in(), 9);
        assert_eq!(l.params(), 9 * 256);
    }

    #[test]
    fn dense_lowering() {
        let l = Layer::dense("fc", 2048, 1000);
        assert_eq!(l.gemm(), GemmShape { m: 1, k: 2048, n: 1000 });
        assert_eq!(l.params(), 2048 * 1000);
    }

    #[test]
    fn gemm_layer_lowering() {
        let l = Layer::gemm_layer("qk", 64, 32, 128, false);
        assert_eq!(l.gemm(), GemmShape { m: 64, k: 32, n: 128 });
        assert_eq!(l.gemm_count(), 1);
        assert_eq!(l.fan_in(), 32);
        assert_eq!(l.params(), 32 * 128);
        assert_eq!(l.macs(), (64 * 32 * 128) as u64);
        assert!(!l.relu_input);
    }

    #[test]
    fn same_padding_output() {
        let l = Layer::conv("c", 7, 3, 64, 2, 224, false);
        assert_eq!(l.out_h(), 112);
        let s1 = Layer::conv("c", 3, 8, 8, 1, 15, true);
        assert_eq!(s1.out_h(), 15);
    }
}

//! MobileNet v1 layer table (Howard et al., 2017), ImageNet 224×224 —
//! the paper's second evaluation workload.
//!
//! Standard 3×3/2 stem, then 13 depthwise-separable pairs (depthwise 3×3
//! + pointwise 1×1), then the classifier. 27 conv layers + fc.

use super::layer::{Layer, Network};

/// (depthwise stride, pointwise cout, input spatial size, cin).
const PAIRS: [(usize, usize, usize, usize); 13] = [
    (1, 64, 112, 32),
    (2, 128, 112, 64),
    (1, 128, 56, 128),
    (2, 256, 56, 128),
    (1, 256, 28, 256),
    (2, 512, 28, 256),
    (1, 512, 14, 512),
    (1, 512, 14, 512),
    (1, 512, 14, 512),
    (1, 512, 14, 512),
    (1, 512, 14, 512),
    (2, 1024, 14, 512),
    (1, 1024, 7, 1024),
];

/// Build the full MobileNet v1 (1.0, 224) layer list.
pub fn mobilenet_v1() -> Network {
    let mut layers = Vec::new();
    layers.push(Layer::conv("conv1", 3, 3, 32, 2, 224, false));
    for (i, &(s, cout, h, cin)) in PAIRS.iter().enumerate() {
        let n = i + 1;
        layers.push(Layer::depthwise(&format!("dw{n}"), cin, s, h));
        let out_h = h.div_ceil(s);
        layers.push(Layer::conv(&format!("pw{n}"), 1, cin, cout, 1, out_h, true));
    }
    layers.push(Layer::dense("fc", 1024, 1000));
    Network { name: "mobilenet".into(), layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LayerKind;

    #[test]
    fn layer_counts() {
        let net = mobilenet_v1();
        assert_eq!(net.layers.len(), 28); // 1 stem + 13 dw + 13 pw + fc
        let dw = net
            .layers
            .iter()
            .filter(|l| l.kind == LayerKind::Depthwise)
            .count();
        assert_eq!(dw, 13);
    }

    #[test]
    fn channel_chain_consistent() {
        let net = mobilenet_v1();
        let mut cin = 32;
        for l in net.layers.iter().skip(1) {
            match l.kind {
                LayerKind::Depthwise => {
                    assert_eq!(l.cin, cin, "layer {}", l.name);
                }
                LayerKind::Conv | LayerKind::Dense | LayerKind::Gemm => {
                    assert_eq!(l.cin, cin, "layer {}", l.name);
                    cin = l.cout;
                }
            }
        }
        assert_eq!(cin, 1000);
    }

    #[test]
    fn param_count_close_to_reference() {
        // MobileNet v1 1.0/224: ~4.2M params (convs + fc, no BN).
        let p = mobilenet_v1().total_params();
        assert!((3_800_000..4_600_000).contains(&p), "params {p}");
    }

    #[test]
    fn mac_count_close_to_reference() {
        // ~569 MMACs at 224×224.
        let m = mobilenet_v1().total_macs();
        assert!((480_000_000..650_000_000).contains(&m), "macs {m}");
    }
}

//! Integration tests for the `engine` facade: builder + batch + streaming
//! APIs, the JSON report schema (golden + round-trip), and the sweep
//! metric edge cases.

use sa_lowpower::activity::ActivityCounts;
use sa_lowpower::coding::SaCodingConfig;
use sa_lowpower::coordinator::{ConfigResult, LayerReport, SweepReport};
use sa_lowpower::engine::{
    BackendKind, ConfigSet, LayerJob, SaEngine, SWEEP_REPORT_SCHEMA,
};
use sa_lowpower::power::EnergyBreakdown;
use sa_lowpower::util::json::Json;
use sa_lowpower::workload::{tinycnn, GemmShape, Layer, Network};

fn fast_engine(configs: ConfigSet, kind: BackendKind) -> SaEngine {
    SaEngine::builder()
        .max_tiles_per_layer(2)
        .configs(configs)
        .backend(kind)
        .threads(2)
        .build()
}

/// A minimal hand-built report whose JSON rendering is fully predictable
/// (every float is an exact binary fraction).
fn handmade_report() -> SweepReport {
    let counts = ActivityCounts {
        west_data_toggles: 10,
        active_macs: 3,
        cycles: 4,
        ..Default::default()
    };
    let energy = EnergyBreakdown {
        west_data: 1.5,
        north_data: 2.0,
        mult: 8.0,
        unload: 1.0,
        ..Default::default()
    };
    SweepReport {
        network: "unit".into(),
        backend: "analytic".into(),
        layers: vec![LayerReport {
            layer_name: "conv1".into(),
            layer_index: 0,
            gemm: GemmShape { m: 4, k: 8, n: 2 },
            input_zero_frac: 0.5,
            sampled_tiles: 1,
            total_tiles: 2,
            results: vec![ConfigResult {
                config: SaCodingConfig::baseline(),
                config_name: "baseline".into(),
                counts,
                energy,
            }],
        }],
    }
}

// ---- JSON schema -----------------------------------------------------

/// Golden test: the report document layout is a public artifact format.
/// If this fails because the schema deliberately changed, bump
/// `SWEEP_REPORT_SCHEMA` and re-pin the string.
#[test]
fn sweep_report_json_schema_is_pinned() {
    let golden = include_str!("golden/sweep_report_v1.json");
    assert_eq!(handmade_report().to_json(), golden);
    assert!(golden.contains(SWEEP_REPORT_SCHEMA));
}

#[test]
fn sweep_report_json_round_trips_from_a_real_sweep() {
    let net = tinycnn();
    let sweep = fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net);
    let doc = Json::parse(&sweep.to_json()).expect("report must be valid JSON");

    assert_eq!(doc.get("schema").unwrap().as_str(), Some(SWEEP_REPORT_SCHEMA));
    assert_eq!(doc.get("network").unwrap().as_str(), Some(net.name.as_str()));
    assert_eq!(doc.get("backend").unwrap().as_str(), Some("analytic"));

    let layers = doc.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), sweep.layers.len());
    for (jl, l) in layers.iter().zip(&sweep.layers) {
        assert_eq!(jl.get("layer").unwrap().as_str(), Some(l.layer_name.as_str()));
        assert_eq!(jl.get("index").unwrap().as_u64(), Some(l.layer_index as u64));
        assert_eq!(
            jl.get("gemm").unwrap().get("k").unwrap().as_u64(),
            Some(l.gemm.k as u64)
        );
        assert_eq!(
            jl.get("input_zero_frac").unwrap().as_f64(),
            Some(l.input_zero_frac)
        );
        let results = jl.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), l.results.len());
        for (jr, r) in results.iter().zip(&l.results) {
            assert_eq!(
                jr.get("config").unwrap().as_str(),
                Some(r.config_name.as_str())
            );
            assert_eq!(
                jr.get("counts").unwrap().get("streaming_toggles").unwrap().as_u64(),
                Some(r.counts.streaming_toggles())
            );
            assert_eq!(
                jr.get("counts").unwrap().get("cycles").unwrap().as_u64(),
                Some(r.counts.cycles)
            );
            // floats survive the render→parse trip exactly (shortest
            // round-trip formatting)
            assert_eq!(
                jr.get("energy").unwrap().get("total").unwrap().as_f64(),
                Some(r.energy.total())
            );
            assert_eq!(
                jr.get("energy").unwrap().get("streaming").unwrap().as_f64(),
                Some(r.energy.streaming())
            );
        }
    }
}

#[test]
fn write_json_creates_parent_dirs() {
    let dir = std::env::temp_dir().join("sa_lowpower_engine_api_test");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("nested").join("report.json");
    handmade_report().write_json(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(Json::parse(&text).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- sweep metric edge cases ----------------------------------------

#[test]
fn sweep_metrics_handle_unknown_config_names() {
    let net = tinycnn();
    let sweep = fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net);
    // unknown names contribute zero energy → savings must be 0, not NaN
    assert_eq!(sweep.total_energy("nope"), 0.0);
    assert_eq!(sweep.overall_savings_pct("nope", "proposed"), 0.0);
    assert_eq!(sweep.streaming_activity_reduction_pct("nope", "proposed"), 0.0);
    let (lo, hi) = sweep.per_layer_savings_range("nope", "proposed");
    assert_eq!((lo, hi), (0.0, 0.0));
}

#[test]
fn sweep_metrics_are_zero_when_a_equals_b() {
    let net = tinycnn();
    let sweep = fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net);
    assert_eq!(sweep.overall_savings_pct("proposed", "proposed"), 0.0);
    assert_eq!(
        sweep.streaming_activity_reduction_pct("proposed", "proposed"),
        0.0
    );
}

#[test]
fn sweep_metrics_survive_zero_energy_baseline() {
    // An empty sweep has zero total energy under every name.
    let empty = SweepReport {
        network: "empty".into(),
        backend: "analytic".into(),
        layers: Vec::new(),
    };
    assert_eq!(empty.overall_savings_pct("baseline", "proposed"), 0.0);
    assert_eq!(empty.streaming_activity_reduction_pct("baseline", "proposed"), 0.0);
    assert_eq!(empty.per_layer_savings_range("baseline", "proposed"), (0.0, 0.0));
    assert!(Json::parse(&empty.to_json()).is_ok());
}

#[test]
fn degenerate_layer_sweeps_to_finite_reports() {
    // Regression: a layer lowering to zero GEMMs (0-channel depthwise)
    // must produce a finite, zeroed report — not NaN, not a panic.
    let net = Network {
        name: "degenerate".into(),
        layers: vec![Layer::depthwise("dw0", 0, 1, 8)],
    };
    let sweep = fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net);
    let l = &sweep.layers[0];
    assert_eq!(l.input_zero_frac, 0.0);
    assert!(l.input_zero_frac.is_finite());
    assert_eq!(l.sampled_tiles, 0);
    assert_eq!(sweep.total_energy("baseline"), 0.0);
    assert_eq!(sweep.overall_savings_pct("baseline", "proposed"), 0.0);
    // and the JSON artifact stays valid (no bare NaN tokens)
    assert!(Json::parse(&sweep.to_json()).is_ok());
}

// ---- batch vs streaming vs backends ---------------------------------

#[test]
fn streaming_api_delivers_every_layer_of_a_network() {
    let net = tinycnn();
    let engine = fast_engine(ConfigSet::paper(), BackendKind::Analytic);
    let handles: Vec<_> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| engine.submit(LayerJob::synthetic(l.clone(), i)))
        .collect();
    let batch = engine.sweep(&net);
    for h in handles {
        let idx = h.layer_index();
        let rep = h.wait();
        assert_eq!(rep.layer_name, net.layers[idx].name);
        assert_eq!(
            rep.energy_of("proposed").unwrap().total(),
            batch.layers[idx].energy_of("proposed").unwrap().total()
        );
    }
}

#[test]
fn cycle_backend_sweep_matches_analytic_sweep() {
    // `--backend cycle` must reproduce the analytic sweep bit-exactly
    // (same counts, hence same energies) — only provenance differs.
    let net = tinycnn();
    let a = fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net);
    let c = fast_engine(ConfigSet::paper(), BackendKind::Cycle).sweep(&net);
    assert_eq!(a.backend, "analytic");
    assert_eq!(c.backend, "cycle");
    for (la, lc) in a.layers.iter().zip(&c.layers) {
        for (ra, rc) in la.results.iter().zip(&lc.results) {
            assert_eq!(ra.counts, rc.counts, "layer {}", la.layer_name);
            assert_eq!(ra.energy, rc.energy, "layer {}", la.layer_name);
        }
    }
}

//! Integration tests for the `engine` facade: builder + batch + streaming
//! APIs, the JSON report schema (golden + round-trip), and the sweep
//! metric edge cases.

use sa_lowpower::activity::ActivityCounts;
use sa_lowpower::coding::{CodingStack, SaCodingConfig};
use sa_lowpower::coordinator::{ConfigResult, LayerReport, SweepReport};
use sa_lowpower::engine::{
    BackendKind, ConfigSet, LayerJob, SaEngine, SweepDoc, SWEEP_REPORT_SCHEMA,
    SWEEP_REPORT_SCHEMA_V1, SWEEP_REPORT_SCHEMA_V2,
};
use sa_lowpower::power::EnergyBreakdown;
use sa_lowpower::util::json::Json;
use sa_lowpower::workload::{tinycnn, transformer, GemmShape, Layer, Network};

fn fast_engine(configs: ConfigSet, kind: BackendKind) -> SaEngine {
    SaEngine::builder()
        .max_tiles_per_layer(2)
        .configs(configs)
        .backend(kind)
        .threads(2)
        .build()
        .unwrap()
}

/// A minimal hand-built report whose JSON rendering is fully predictable
/// (every float is an exact binary fraction).
fn handmade_report() -> SweepReport {
    let counts = ActivityCounts {
        west_data_toggles: 10,
        active_macs: 3,
        cycles: 4,
        ..Default::default()
    };
    let energy = EnergyBreakdown {
        west_data: 1.5,
        north_data: 2.0,
        mult: 8.0,
        unload: 1.0,
        ..Default::default()
    };
    SweepReport {
        network: "unit".into(),
        backend: "analytic".into(),
        dataflow: "ws".into(),
        cache: None,
        layers: vec![LayerReport {
            layer_name: "conv1".into(),
            layer_index: 0,
            gemm: GemmShape { m: 4, k: 8, n: 2 },
            input_zero_frac: 0.5,
            sampled_tiles: 1,
            total_tiles: 2,
            results: vec![ConfigResult {
                stack: CodingStack::baseline(),
                config_name: "baseline".into(),
                // half the tiles sampled → scale 2 on the extrapolated
                // streaming toggles (in-memory aggregation field; the v3
                // document intentionally carries only the raw ledger)
                scaled_streaming_toggles: 2.0 * counts.streaming_toggles() as f64,
                counts,
                energy,
                specialized: false,
            }],
            faults: Vec::new(),
        }],
    }
}

/// A hand-built report over the *real* transformer workload's layer
/// metadata (names, GEMM shapes, tile-grid totals come from
/// `workload::transformer()`), with exact-binary activity/energy values
/// so the rendering is byte-stable. Pins the v2 document layout for the
/// new workload; if the transformer shapes change, this golden breaks
/// loudly.
fn handmade_transformer_report() -> SweepReport {
    let net = transformer();
    let qkv = &net.layers[0]; // blk1.qkv: 64×256×768
    let ffn_down = &net.layers[5]; // blk1.ffn.down: 64×1024×256
    assert_eq!(qkv.name, "blk1.qkv");
    assert_eq!(ffn_down.name, "blk1.ffn.down");
    let qkv_counts = ActivityCounts {
        west_data_toggles: 2048,
        west_clock_events: 16384,
        north_data_toggles: 4096,
        north_clock_events: 12288,
        mult_input_toggles: 6144,
        active_macs: 1024,
        acc_clock_events: 32768,
        unload_values: 256,
        cycles: 257,
        ..Default::default()
    };
    let qkv_energy = EnergyBreakdown {
        west_data: 3.5,
        west_clock: 2.25,
        north_data: 7.25,
        north_clock: 1.5,
        mult: 512.0,
        add_acc: 389.0,
        acc_clock: 29.5,
        unload: 38.5,
        ..Default::default()
    };
    let ffn_counts = ActivityCounts {
        west_data_toggles: 1024,
        west_clock_events: 8192,
        west_sideband_toggles: 64,
        west_sideband_clock_events: 1024,
        zero_detect_ops: 1024,
        west_cg_cell_cycles: 4096,
        north_data_toggles: 1536,
        north_clock_events: 6144,
        north_sideband_toggles: 96,
        north_sideband_clock_events: 1024,
        encoder_ops: 1024,
        decoder_toggles: 512,
        mult_input_toggles: 2048,
        active_macs: 512,
        gated_macs: 512,
        acc_clock_events: 16384,
        acc_cg_cell_cycles: 1024,
        unload_values: 256,
        cycles: 1025,
        ..Default::default()
    };
    let ffn_energy = EnergyBreakdown {
        west_data: 1.75,
        west_clock: 2.5,
        west_gating: 3.125,
        north_data: 5.5,
        north_clock: 1.25,
        north_coding: 10.25,
        mult: 256.5,
        add_acc: 194.5,
        acc_clock: 14.75,
        unload: 38.5,
    };
    SweepReport {
        network: net.name.clone(),
        backend: "cycle".into(),
        dataflow: "os".into(),
        cache: None,
        layers: vec![
            LayerReport {
                layer_name: qkv.name.clone(),
                layer_index: 0,
                gemm: qkv.gemm(),
                input_zero_frac: 0.125,
                sampled_tiles: 1,
                total_tiles: 192,
                results: vec![ConfigResult {
                    stack: CodingStack::baseline(),
                    config_name: "baseline".into(),
                    scaled_streaming_toggles: 192.0
                        * qkv_counts.streaming_toggles() as f64,
                    counts: qkv_counts,
                    energy: qkv_energy,
                    specialized: false,
                }],
                faults: Vec::new(),
            },
            LayerReport {
                layer_name: ffn_down.name.clone(),
                layer_index: 5,
                gemm: ffn_down.gemm(),
                input_zero_frac: 0.5,
                sampled_tiles: 1,
                total_tiles: 64,
                results: vec![ConfigResult {
                    stack: SaCodingConfig::proposed().stack(),
                    config_name: "proposed".into(),
                    scaled_streaming_toggles: 64.0
                        * ffn_counts.streaming_toggles() as f64,
                    counts: ffn_counts,
                    energy: ffn_energy,
                    specialized: false,
                }],
                faults: Vec::new(),
            },
        ],
    }
}

// ---- JSON schema -----------------------------------------------------

/// Golden test: the report document layout is a public artifact format.
/// If this fails because the schema deliberately changed, bump
/// `SWEEP_REPORT_SCHEMA` and re-pin the string.
#[test]
fn sweep_report_json_schema_is_pinned() {
    let golden = include_str!("golden/sweep_report_v3.json");
    assert_eq!(handmade_report().to_json(), golden);
    assert!(golden.contains(SWEEP_REPORT_SCHEMA));
}

/// Backward compatibility: v2 documents (pre-stack) must keep parsing.
/// The committed v2 golden file is the compat fixture; its per-result
/// fields still read under the v3 walker (the v3 additions — the
/// "stack" object and comparator count fields — are strictly additive).
#[test]
fn v2_schema_documents_remain_parseable() {
    let v2 = include_str!("golden/sweep_report_v2.json");
    let doc = SweepDoc::parse(v2).expect("v2 must stay readable");
    assert_eq!(doc.schema, SWEEP_REPORT_SCHEMA_V2);
    assert_eq!(doc.network, "unit");
    assert_eq!(doc.dataflow, "ws");
    assert_eq!(doc.layer_count, 1);
    let json = Json::parse(v2).unwrap();
    let result = json
        .get("layers")
        .unwrap()
        .idx(0)
        .unwrap()
        .get("results")
        .unwrap()
        .idx(0)
        .unwrap();
    // v2 predates the per-stream stack provenance and comparator fields
    assert!(result.get("stack").is_none());
    assert!(result
        .get("counts")
        .unwrap()
        .get("west_comparator_bit_cycles")
        .is_none());
    assert_eq!(result.get("coding").unwrap().as_str(), Some("baseline"));
}

/// Backward compatibility: v1 documents (pre-dataflow) must keep
/// parsing, with the dataflow defaulting to the only machine that
/// existed then. The committed v1 golden file is the compat fixture.
#[test]
fn v1_schema_documents_remain_parseable() {
    let v1 = include_str!("golden/sweep_report_v1.json");
    let doc = SweepDoc::parse(v1).expect("v1 must stay readable");
    assert_eq!(doc.schema, SWEEP_REPORT_SCHEMA_V1);
    assert_eq!(doc.network, "unit");
    assert_eq!(doc.backend, "analytic");
    assert_eq!(doc.dataflow, "ws");
    assert_eq!(doc.layer_count, 1);
    // the v1 body predates the field entirely
    let json = Json::parse(v1).unwrap();
    assert!(json.get("dataflow").is_none());
    // and the v1 fixture differs from v2 only by schema tag + dataflow:
    // every v1 layer field still parses under the v2 walker
    let layer = json.get("layers").unwrap().idx(0).unwrap();
    assert_eq!(layer.get("layer").unwrap().as_str(), Some("conv1"));
    assert_eq!(layer.get("gemm").unwrap().get("k").unwrap().as_u64(), Some(8));
}

/// Golden test for the transformer workload: the v3 document over real
/// transformer layer metadata is pinned byte-for-byte, and the v2
/// rendering of the same report is kept as a reader-compat fixture.
#[test]
fn transformer_sweep_report_golden() {
    let golden = include_str!("golden/sweep_report_transformer_v3.json");
    assert_eq!(handmade_transformer_report().to_json(), golden);
    let doc = SweepDoc::parse(golden).unwrap();
    assert_eq!(doc.schema, SWEEP_REPORT_SCHEMA);
    assert_eq!(doc.network, "transformer");
    assert_eq!(doc.backend, "cycle");
    assert_eq!(doc.dataflow, "os");
    assert_eq!(doc.layer_count, 2);

    let v2 = include_str!("golden/sweep_report_transformer_v2.json");
    let doc2 = SweepDoc::parse(v2).expect("v2 transformer fixture stays readable");
    assert_eq!(doc2.schema, SWEEP_REPORT_SCHEMA_V2);
    assert_eq!(doc2.dataflow, "os");
    // the v2 fixture used the old display-only coding format; v3 made
    // it a parseable spec — both name the same design
    let old_coding = Json::parse(v2)
        .unwrap()
        .get("layers")
        .unwrap()
        .idx(1)
        .unwrap()
        .get("results")
        .unwrap()
        .idx(0)
        .unwrap()
        .get("coding")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    assert_eq!(old_coding, "w:bic-mantissa+i:zvcg");
    assert!(CodingStack::parse(&old_coding).is_err(), "old format, unparseable");
    assert_eq!(
        SaCodingConfig::proposed().describe(),
        "w:bic-mantissa,i:zvcg",
        "the drift the spec grammar fixed"
    );
}

#[test]
fn sweep_report_json_round_trips_from_a_real_sweep() {
    let net = tinycnn();
    let sweep =
        fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net).unwrap();
    let doc = Json::parse(&sweep.to_json()).expect("report must be valid JSON");

    assert_eq!(doc.get("schema").unwrap().as_str(), Some(SWEEP_REPORT_SCHEMA));
    assert_eq!(doc.get("network").unwrap().as_str(), Some(net.name.as_str()));
    assert_eq!(doc.get("backend").unwrap().as_str(), Some("analytic"));
    assert_eq!(doc.get("dataflow").unwrap().as_str(), Some("ws"));

    let layers = doc.get("layers").unwrap().as_arr().unwrap();
    assert_eq!(layers.len(), sweep.layers.len());
    for (jl, l) in layers.iter().zip(&sweep.layers) {
        assert_eq!(jl.get("layer").unwrap().as_str(), Some(l.layer_name.as_str()));
        assert_eq!(jl.get("index").unwrap().as_u64(), Some(l.layer_index as u64));
        assert_eq!(
            jl.get("gemm").unwrap().get("k").unwrap().as_u64(),
            Some(l.gemm.k as u64)
        );
        assert_eq!(
            jl.get("input_zero_frac").unwrap().as_f64(),
            Some(l.input_zero_frac)
        );
        let results = jl.get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), l.results.len());
        for (jr, r) in results.iter().zip(&l.results) {
            assert_eq!(
                jr.get("config").unwrap().as_str(),
                Some(r.config_name.as_str())
            );
            // the coding string is the canonical spec and re-parses to
            // the stack that produced the counts
            let coding = jr.get("coding").unwrap().as_str().unwrap();
            assert_eq!(CodingStack::parse(coding).unwrap(), r.stack);
            // per-stream stack provenance
            let js = jr.get("stack").unwrap();
            let names = |edge: &str| -> Vec<String> {
                js.get(edge)
                    .unwrap()
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|v| v.as_str().unwrap().to_string())
                    .collect()
            };
            assert_eq!(
                names("west"),
                r.stack.west.codecs().iter().map(|c| c.name()).collect::<Vec<_>>()
            );
            assert_eq!(
                names("north"),
                r.stack.north.codecs().iter().map(|c| c.name()).collect::<Vec<_>>()
            );
            assert_eq!(
                jr.get("counts").unwrap().get("streaming_toggles").unwrap().as_u64(),
                Some(r.counts.streaming_toggles())
            );
            assert_eq!(
                jr.get("counts").unwrap().get("cycles").unwrap().as_u64(),
                Some(r.counts.cycles)
            );
            // floats survive the render→parse trip exactly (shortest
            // round-trip formatting)
            assert_eq!(
                jr.get("energy").unwrap().get("total").unwrap().as_f64(),
                Some(r.energy.total())
            );
            assert_eq!(
                jr.get("energy").unwrap().get("streaming").unwrap().as_f64(),
                Some(r.energy.streaming())
            );
        }
    }
}

/// Tile-granular scheduling must not leak scheduling nondeterminism
/// into reports: the rendered JSON document — every f64 included — is
/// byte-identical regardless of pool width, because per-tile costs are
/// folded in plan order no matter which worker priced them.
#[test]
fn sweep_report_json_is_byte_identical_across_thread_counts() {
    let net = tinycnn();
    let render = |threads: usize, kind: BackendKind| {
        SaEngine::builder()
            .max_tiles_per_layer(8)
            .configs(ConfigSet::ablation())
            .backend(kind)
            .threads(threads)
            .build()
            .unwrap()
            .sweep(&net)
            .unwrap()
            .to_json()
    };
    for kind in [BackendKind::Analytic, BackendKind::Cycle] {
        let one = render(1, kind);
        for threads in [2, 4, 7] {
            assert_eq!(
                one,
                render(threads, kind),
                "JSON drift at {threads} threads ({} backend)",
                kind.name()
            );
        }
    }
}

/// The scale-extrapolated streaming toggles ride along every sweep
/// result and feed `streaming_activity_reduction_pct`; on a fully
/// sampled layer they coincide with the raw ledger sum.
#[test]
fn scaled_streaming_toggles_flow_through_sweeps() {
    let net = tinycnn();
    let sweep = SaEngine::builder()
        .max_tiles_per_layer(10_000)
        .configs(ConfigSet::paper())
        .threads(2)
        .build()
        .unwrap()
        .sweep(&net)
        .unwrap();
    for l in &sweep.layers {
        for r in &l.results {
            if l.sampled_tiles == l.total_tiles
                && !matches!(
                    net.layers[l.layer_index].kind,
                    sa_lowpower::workload::LayerKind::Depthwise
                )
            {
                assert_eq!(
                    r.scaled_streaming_toggles,
                    r.counts.streaming_toggles() as f64,
                    "layer {} config {}",
                    l.layer_name,
                    r.config_name
                );
            }
            assert!(r.scaled_streaming_toggles >= r.counts.streaming_toggles() as f64);
        }
    }
    assert!(sweep.streaming_activity_reduction_pct("baseline", "proposed") > 0.0);
}

#[test]
fn write_json_creates_parent_dirs() {
    let dir = std::env::temp_dir().join("sa_lowpower_engine_api_test");
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("nested").join("report.json");
    handmade_report().write_json(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(Json::parse(&text).is_ok());
    let _ = std::fs::remove_dir_all(&dir);
}

// ---- sweep metric edge cases ----------------------------------------

#[test]
fn sweep_metrics_handle_unknown_config_names() {
    let net = tinycnn();
    let sweep =
        fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net).unwrap();
    // unknown names contribute zero energy → savings must be 0, not NaN
    assert_eq!(sweep.total_energy("nope"), 0.0);
    assert_eq!(sweep.overall_savings_pct("nope", "proposed"), 0.0);
    assert_eq!(sweep.streaming_activity_reduction_pct("nope", "proposed"), 0.0);
    let (lo, hi) = sweep.per_layer_savings_range("nope", "proposed");
    assert_eq!((lo, hi), (0.0, 0.0));
}

#[test]
fn sweep_metrics_are_zero_when_a_equals_b() {
    let net = tinycnn();
    let sweep =
        fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net).unwrap();
    assert_eq!(sweep.overall_savings_pct("proposed", "proposed"), 0.0);
    assert_eq!(
        sweep.streaming_activity_reduction_pct("proposed", "proposed"),
        0.0
    );
}

#[test]
fn sweep_metrics_survive_zero_energy_baseline() {
    // An empty sweep has zero total energy under every name.
    let empty = SweepReport {
        network: "empty".into(),
        backend: "analytic".into(),
        dataflow: "ws".into(),
        cache: None,
        layers: Vec::new(),
    };
    assert_eq!(empty.overall_savings_pct("baseline", "proposed"), 0.0);
    assert_eq!(empty.streaming_activity_reduction_pct("baseline", "proposed"), 0.0);
    assert_eq!(empty.per_layer_savings_range("baseline", "proposed"), (0.0, 0.0));
    assert!(Json::parse(&empty.to_json()).is_ok());
}

#[test]
fn degenerate_layer_sweeps_to_finite_reports() {
    // Regression: a layer lowering to zero GEMMs (0-channel depthwise)
    // must produce a finite, zeroed report — not NaN, not a panic.
    let net = Network {
        name: "degenerate".into(),
        layers: vec![Layer::depthwise("dw0", 0, 1, 8)],
    };
    let sweep =
        fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net).unwrap();
    let l = &sweep.layers[0];
    assert_eq!(l.input_zero_frac, 0.0);
    assert!(l.input_zero_frac.is_finite());
    assert_eq!(l.sampled_tiles, 0);
    assert_eq!(sweep.total_energy("baseline"), 0.0);
    assert_eq!(sweep.overall_savings_pct("baseline", "proposed"), 0.0);
    // and the JSON artifact stays valid (no bare NaN tokens)
    assert!(Json::parse(&sweep.to_json()).is_ok());
}

// ---- batch vs streaming vs backends ---------------------------------

#[test]
fn streaming_api_delivers_every_layer_of_a_network() {
    let net = tinycnn();
    let engine = fast_engine(ConfigSet::paper(), BackendKind::Analytic);
    let handles: Vec<_> = net
        .layers
        .iter()
        .enumerate()
        .map(|(i, l)| engine.submit(LayerJob::synthetic(l.clone(), i)).unwrap())
        .collect();
    let batch = engine.sweep(&net).unwrap();
    for h in handles {
        let idx = h.layer_index();
        let rep = h.wait().unwrap();
        assert_eq!(rep.layer_name, net.layers[idx].name);
        assert_eq!(
            rep.energy_of("proposed").unwrap().total(),
            batch.layers[idx].energy_of("proposed").unwrap().total()
        );
    }
}

#[test]
fn cycle_backend_sweep_matches_analytic_sweep() {
    // `--backend cycle` must reproduce the analytic sweep bit-exactly
    // (same counts, hence same energies) — only provenance differs.
    let net = tinycnn();
    let a = fast_engine(ConfigSet::paper(), BackendKind::Analytic).sweep(&net).unwrap();
    let c = fast_engine(ConfigSet::paper(), BackendKind::Cycle).sweep(&net).unwrap();
    assert_eq!(a.backend, "analytic");
    assert_eq!(c.backend, "cycle");
    for (la, lc) in a.layers.iter().zip(&c.layers) {
        for (ra, rc) in la.results.iter().zip(&lc.results) {
            assert_eq!(ra.counts, rc.counts, "layer {}", la.layer_name);
            assert_eq!(ra.energy, rc.energy, "layer {}", la.layer_name);
        }
    }
}

//! Edge-case battery for the `util::json` parser/renderer — previously
//! exercised only indirectly through the report goldens. Covers escape
//! sequences, nested arrays, NaN/infinity rejection, and round-trips on
//! deep trees.

use sa_lowpower::util::json::Json;

// ---- escape sequences ------------------------------------------------

#[test]
fn every_renderer_escape_round_trips() {
    // quote, backslash, newline, tab, CR, and raw control chars (the
    // renderer emits \u00xx for those)
    let cases = [
        "plain",
        "quote\"inside",
        "back\\slash",
        "line\nbreak",
        "tab\tstop",
        "carriage\rreturn",
        "ctrl\u{1}\u{2}\u{1f}",
        "mixed \"\\\n\t\r\u{7} end",
        "unicode: π 😀 Ω",
        "", // empty string
    ];
    for s in cases {
        let v = Json::Str(s.to_string());
        let text = v.render();
        assert_eq!(Json::parse(&text).unwrap(), v, "case {s:?}");
    }
}

#[test]
fn parser_accepts_standard_escapes_the_renderer_never_emits() {
    assert_eq!(Json::parse(r#""a\/b""#).unwrap(), Json::Str("a/b".into()));
    assert_eq!(
        Json::parse(r#""\b\f""#).unwrap(),
        Json::Str("\u{8}\u{c}".into())
    );
    assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    // escaped keys too, not just values
    let doc = Json::parse(r#"{"a\nb": 1}"#).unwrap();
    assert_eq!(doc.get("a\nb").unwrap().as_u64(), Some(1));
}

#[test]
fn parser_rejects_bad_escapes() {
    assert!(Json::parse(r#""\q""#).is_err());
    assert!(Json::parse(r#""\u12""#).is_err(), "truncated \\u");
    assert!(Json::parse(r#""\u12zz""#).is_err(), "non-hex \\u");
    assert!(Json::parse("\"unterminated").is_err());
}

// ---- nested arrays ---------------------------------------------------

#[test]
fn nested_arrays_parse_and_round_trip() {
    let text = "[[1, [2, [3, [4]]]], [], [[]], [5, 6]]";
    let v = Json::parse(text).unwrap();
    assert_eq!(
        v.idx(0).unwrap().idx(1).unwrap().idx(1).unwrap().idx(1).unwrap().idx(0),
        Some(&Json::Num(4.0))
    );
    assert_eq!(v.idx(1).unwrap(), &Json::Arr(vec![]));
    assert_eq!(v.idx(2).unwrap().idx(0), Some(&Json::Arr(vec![])));
    // render → parse is identity
    assert_eq!(Json::parse(&v.render()).unwrap(), v);
}

#[test]
fn arrays_of_objects_of_arrays() {
    let text = r#"[{"rows": [[1, 2], [3, 4]]}, {"rows": []}]"#;
    let v = Json::parse(text).unwrap();
    let rows = v.idx(0).unwrap().get("rows").unwrap();
    assert_eq!(rows.idx(1).unwrap().idx(0).unwrap().as_u64(), Some(3));
    assert_eq!(Json::parse(&v.render()).unwrap(), v);
}

// ---- NaN / infinity rejection ----------------------------------------

#[test]
fn non_finite_tokens_are_rejected() {
    for bad in [
        "NaN", "nan", "Infinity", "-Infinity", "inf", "-inf",
        "[1, NaN]", r#"{"x": Infinity}"#,
    ] {
        assert!(Json::parse(bad).is_err(), "accepted {bad:?}");
    }
}

#[test]
fn overflowing_literals_cannot_smuggle_infinity() {
    // 1e999 overflows f64 to +inf; the parser must refuse rather than
    // produce a non-finite number it could never render back.
    assert!(Json::parse("1e999").is_err());
    assert!(Json::parse("-1e999").is_err());
    assert!(Json::parse(r#"{"e": 1e999}"#).is_err());
    // large but finite literals stay fine
    assert_eq!(Json::parse("1e308").unwrap().as_f64(), Some(1e308));
}

#[test]
fn non_finite_values_render_as_null() {
    assert_eq!(Json::Num(f64::NAN).render(), "null\n");
    assert_eq!(Json::Num(f64::INFINITY).render(), "null\n");
    assert_eq!(Json::Num(f64::NEG_INFINITY).render(), "null\n");
    // so a rendered tree containing them is still valid JSON
    let mut o = Json::object();
    o.push("bad", f64::NAN);
    assert!(Json::parse(&o.render()).is_ok());
}

// ---- deep trees ------------------------------------------------------

#[test]
fn deep_array_nesting_round_trips() {
    let mut v = Json::Num(7.0);
    for _ in 0..64 {
        v = Json::Arr(vec![v]);
    }
    let text = v.render();
    let parsed = Json::parse(&text).unwrap();
    assert_eq!(parsed, v);
    // spot-check the innermost value survived
    let mut cur = &parsed;
    for _ in 0..64 {
        cur = cur.idx(0).unwrap();
    }
    assert_eq!(cur.as_f64(), Some(7.0));
}

#[test]
fn deep_object_chain_round_trips() {
    let mut v = Json::Str("leaf".into());
    for i in 0..64 {
        let mut o = Json::object();
        o.push(&format!("level{i}"), v);
        v = o;
    }
    let back = Json::parse(&v.render()).unwrap();
    assert_eq!(back, v);
    let mut cur = &back;
    for i in (0..64).rev() {
        cur = cur.get(&format!("level{i}")).unwrap();
    }
    assert_eq!(cur.as_str(), Some("leaf"));
}

#[test]
fn wide_and_deep_mixed_tree_round_trips() {
    // a report-shaped tree: arrays of objects with numeric leaves at
    // exact binary fractions (the renderer's losslessness domain)
    let mut layers = Vec::new();
    for i in 0..40 {
        let mut layer = Json::object();
        layer.push("index", i as u64);
        layer.push("frac", (i as f64) * 0.25);
        layer.push(
            "counts",
            Json::Arr((0..10).map(|j| Json::from((i * j) as u64)).collect()),
        );
        layers.push(layer);
    }
    let mut doc = Json::object();
    doc.push("layers", Json::Arr(layers));
    let back = Json::parse(&doc.render()).unwrap();
    assert_eq!(back, doc);
    assert_eq!(
        back.get("layers").unwrap().idx(39).unwrap().get("frac").unwrap().as_f64(),
        Some(9.75)
    );
}

//! Integration: SA simulators × tiler × power model on realistic GEMMs.

use sa_lowpower::bf16::{matmul_f32acc, Bf16};
use sa_lowpower::coding::{CodingStack, SaCodingConfig};
use sa_lowpower::power::EnergyModel;
use sa_lowpower::sa::{analyze_tile, simulate_tile, Dataflow, SaConfig};
use sa_lowpower::util::Rng64;
use sa_lowpower::workload::{extract_tile, Gemm, GemmShape, TileGrid, TilePlan};

fn random_gemm(rng: &mut Rng64, m: usize, k: usize, n: usize, pz: f64) -> Gemm {
    let a: Vec<f32> = (0..m * k)
        .map(|_| if rng.chance(pz) { 0.0 } else { rng.normal() as f32 })
        .collect();
    let b: Vec<f32> = (0..k * n).map(|_| (rng.normal() * 0.1) as f32).collect();
    Gemm::new(a, b, GemmShape { m, k, n })
}

#[test]
fn full_gemm_through_tiles_is_functionally_exact() {
    let mut rng = Rng64::new(42);
    let g = random_gemm(&mut rng, 37, 29, 21, 0.4);
    let a16: Vec<Bf16> = g.a.iter().map(|&x| Bf16::from_f32(x)).collect();
    let b16: Vec<Bf16> = g.b.iter().map(|&x| Bf16::from_f32(x)).collect();
    let want = matmul_f32acc(&a16, &b16, 37, 29, 21);

    let grid = TileGrid::of(g.shape, 16, 16);
    let mut got = vec![0f32; 37 * 21];
    for mi in 0..grid.m_tiles {
        for ni in 0..grid.n_tiles {
            let t = extract_tile(&g, &grid, mi, ni);
            // run through the *proposed* design — gating must not change
            // the numbers
            let r = simulate_tile(
                &t,
                &SaCodingConfig::proposed().stack(),
                Dataflow::WeightStationary,
            );
            for row in 0..t.m {
                for col in 0..t.n {
                    got[(mi * 16 + row) * 21 + (ni * 16 + col)] = r.c[row * t.n + col];
                }
            }
        }
    }
    assert_eq!(got, want);
}

#[test]
fn sampled_energy_extrapolates_consistently() {
    // Sampling all tiles with scale 1 must equal summing every tile.
    let mut rng = Rng64::new(7);
    let g = random_gemm(&mut rng, 48, 32, 48, 0.5);
    let grid = TileGrid::of(g.shape, 16, 16);
    let plan = TilePlan::exhaustive(&grid);
    assert_eq!(plan.picks.len(), 9);

    let model = EnergyModel::default();
    let mut total = 0.0;
    for &(mi, ni) in &plan.picks {
        let t = extract_tile(&g, &grid, mi, ni);
        let c = analyze_tile(
            &t,
            &SaCodingConfig::proposed().stack(),
            Dataflow::WeightStationary,
        );
        total += model.energy(&c).total();
    }
    // sampled at half, scaled: expect same order (not exact — different
    // tiles differ), within 35 %
    let sample = TilePlan::sample(&grid, 4, 123);
    let mut sampled = 0.0;
    for &(mi, ni) in &sample.picks {
        let t = extract_tile(&g, &grid, mi, ni);
        let c = analyze_tile(
            &t,
            &SaCodingConfig::proposed().stack(),
            Dataflow::WeightStationary,
        );
        sampled += model.energy(&c).total();
    }
    sampled *= sample.scale;
    let rel = (sampled - total).abs() / total;
    assert!(rel < 0.35, "extrapolation error {rel}");
}

#[test]
fn proposed_beats_baseline_on_relu_like_gemm() {
    let mut rng = Rng64::new(9);
    let g = random_gemm(&mut rng, 64, 128, 32, 0.55);
    let grid = TileGrid::of(g.shape, 16, 16);
    let model = EnergyModel::default();
    let (mut base, mut prop) = (0.0, 0.0);
    for &(mi, ni) in &TilePlan::exhaustive(&grid).picks {
        let t = extract_tile(&g, &grid, mi, ni);
        base += model
            .energy(&analyze_tile(
                &t,
                &CodingStack::baseline(),
                Dataflow::WeightStationary,
            ))
            .total();
        prop += model
            .energy(&analyze_tile(
                &t,
                &SaCodingConfig::proposed().stack(),
                Dataflow::WeightStationary,
            ))
            .total();
    }
    let savings = 100.0 * (base - prop) / base;
    // paper's per-layer band is 1–19 %; at 55 % zeros expect solid savings
    assert!(
        (2.0..30.0).contains(&savings),
        "savings {savings}% out of plausible band"
    );
}

#[test]
fn cycle_and_analytic_agree_through_the_tiler() {
    let mut rng = Rng64::new(11);
    let g = random_gemm(&mut rng, 40, 24, 40, 0.5);
    let grid = TileGrid::of(g.shape, 16, 16);
    for &(mi, ni) in &TilePlan::exhaustive(&grid).picks {
        let t = extract_tile(&g, &grid, mi, ni);
        for cfg in [
            SaCodingConfig::baseline().stack(),
            SaCodingConfig::proposed().stack(),
            SaCodingConfig::bic_only().stack(),
            SaCodingConfig::zvcg_only().stack(),
            CodingStack::parse("w:ddcg16-g4,i:ddcg16-g4").unwrap(),
        ] {
            for df in [Dataflow::WeightStationary, Dataflow::OutputStationary] {
                assert_eq!(
                    analyze_tile(&t, &cfg, df),
                    simulate_tile(&t, &cfg, df).counts
                );
            }
        }
    }
}

#[test]
fn area_report_consistent_with_paper_claims() {
    let sa = SaConfig::proposed();
    let report = sa.area_report();
    assert!((report.overhead_pct() - 5.7).abs() < 0.4);
    // baseline SA has zero overhead
    assert_eq!(SaConfig::baseline().area_report().overhead_ge, 0.0);
}

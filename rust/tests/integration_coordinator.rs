//! Integration: the e2e inference server (XLA forward pass) + SA power
//! analysis on real activations, plus rust↔XLA functional cross-checks.
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use std::path::PathBuf;

use sa_lowpower::bf16::{matmul_f32acc, Bf16};
use sa_lowpower::coordinator::{synthetic_image, InferenceServer, TinycnnParams};
use sa_lowpower::engine::{ConfigSet, SaEngine};
use sa_lowpower::workload::{im2col_same, tinycnn};

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn inference_server_end_to_end() {
    let dir = require_artifacts!();
    let params = TinycnnParams::generate(7);
    let server = InferenceServer::start(&dir, params).unwrap();

    let resp = server.infer(synthetic_image(1)).unwrap();
    assert_eq!(resp.logits.len(), 10);
    assert!(resp.logits.iter().all(|v| v.is_finite()));
    assert_eq!(resp.activations.len(), 5);
    // ReLU invariants + emergent sparsity
    for (i, a) in resp.activations.iter().enumerate() {
        assert!(a.iter().all(|&v| v >= 0.0), "act {i} negative");
    }
    for (i, &z) in resp.zero_fractions.iter().enumerate() {
        assert!((0.1..0.9).contains(&z), "act {i} zero frac {z}");
    }
    assert_eq!(server.metrics.requests(), 1);
}

#[test]
fn inference_is_deterministic() {
    let dir = require_artifacts!();
    let server = InferenceServer::start(&dir, TinycnnParams::generate(3)).unwrap();
    let r1 = server.infer(synthetic_image(9)).unwrap();
    let r2 = server.infer(synthetic_image(9)).unwrap();
    assert_eq!(r1.logits, r2.logits);
    assert_eq!(r1.activations, r2.activations);
}

#[test]
fn rust_gemm_matches_xla_layer1_activation() {
    // Cross-language functional check: layer-1 conv computed in rust
    // (im2col + bf16 matmul) must match the XLA artifact's activation.
    let dir = require_artifacts!();
    let params = TinycnnParams::generate(5);
    let server = InferenceServer::start(&dir, params.clone()).unwrap();
    let image = synthetic_image(2);
    let resp = server.infer(image.clone()).unwrap();

    let net = tinycnn();
    let l = &net.layers[0]; // conv1: 3x3, 3->16, s1, 32x32
    let a = im2col_same(&image, l.h, l.w, l.cin, l.kh, l.kw, l.stride);
    let g = l.gemm();
    let a16: Vec<Bf16> = a.iter().map(|&x| Bf16::from_f32(x)).collect();
    let b16: Vec<Bf16> = params.gemm_weights(0).iter().map(|&x| Bf16::from_f32(x)).collect();
    let c = matmul_f32acc(&a16, &b16, g.m, g.k, g.n);

    let xla_act = &resp.activations[0]; // post-ReLU NHWC
    assert_eq!(xla_act.len(), c.len());
    let mut max_err = 0f32;
    for (got, want) in c.iter().zip(xla_act) {
        let relu = got.max(0.0);
        max_err = max_err.max((relu - want).abs());
    }
    assert!(max_err < 2e-2, "rust vs XLA layer-1 max err {max_err}");
}

#[test]
fn power_on_real_activations_shows_savings() {
    let dir = require_artifacts!();
    let params = TinycnnParams::generate(11);
    let server = InferenceServer::start(&dir, params.clone()).unwrap();
    let image = synthetic_image(4);
    let resp = server.infer(image.clone()).unwrap();

    let net = tinycnn();
    let engine = SaEngine::builder()
        .max_tiles_per_layer(8)
        .configs(ConfigSet::paper())
        .build()
        .unwrap();
    // layer 2 input = activation 1 (real, ~50 % zeros from ReLU)
    let rep = engine.analyze_layer_with_data(
        &net.layers[1],
        1,
        resp.activations[0].clone(),
        params.gemm_weights(1).to_vec(),
    )
    .unwrap();
    assert!(rep.input_zero_frac > 0.2, "zeros {}", rep.input_zero_frac);
    let s = rep.savings_pct("baseline", "proposed").unwrap();
    assert!(s > 1.0, "savings on real activations: {s}%");
}

#[test]
fn server_handles_concurrent_callers() {
    let dir = require_artifacts!();
    let server = std::sync::Arc::new(
        InferenceServer::start(&dir, TinycnnParams::generate(1)).unwrap(),
    );
    std::thread::scope(|s| {
        for t in 0..4 {
            let server = std::sync::Arc::clone(&server);
            s.spawn(move || {
                let r = server.infer(synthetic_image(100 + t)).unwrap();
                assert_eq!(r.logits.len(), 10);
            });
        }
    });
    assert_eq!(server.metrics.requests(), 4);
    assert_eq!(server.metrics.errors(), 0);
}

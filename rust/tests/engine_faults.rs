//! Robustness integration suite: deterministic fault injection against
//! the live worker pool. Exercises the full failure partition — caller
//! errors rejected at the submit boundary, job errors contained to one
//! job, pool survival across panics — plus admission backpressure,
//! deadlines, cancellation and graceful drain.
//!
//! The acceptance contract pinned here: a fault-injected panic at tile N
//! yields a typed error (or partial report) for that job ONLY, and a
//! subsequent job on the same pool is byte-identical to a fresh-pool
//! run.

use std::time::{Duration, Instant};

use sa_lowpower::engine::{
    AdmissionPolicy, ConfigSet, EngineError, FaultPlan, LayerJob, SaEngine,
    TileFailurePolicy, MAX_THREADS,
};
use sa_lowpower::workload::{tinycnn, Layer};

/// A layer big enough to split into several tile items on the default
/// 16×16 array (64×32×64 GEMM → a 4×4 tile grid before sampling).
fn victim_layer() -> Layer {
    Layer::gemm_layer("victim", 64, 32, 64, false)
}

fn builder_with(fault: &str) -> sa_lowpower::engine::SaEngineBuilder {
    SaEngine::builder()
        .max_tiles_per_layer(4)
        .configs(ConfigSet::paper())
        .threads(2)
        .fault_plan(FaultPlan::parse(fault).unwrap())
}

// ---- containment: one job fails, the pool and its peers don't -------

#[test]
fn panic_at_tile_n_fails_only_that_job_and_pool_output_stays_byte_exact() {
    let net = tinycnn();
    let armed = builder_with("panic@victim:1").build().unwrap();

    // The doomed job: tile item 1 panics mid-pricing.
    let doomed = armed.submit(LayerJob::synthetic(victim_layer(), 7)).unwrap();
    match doomed.wait() {
        Err(EngineError::WorkerPanic { context, .. }) => {
            assert!(context.contains("victim"), "context names the layer: {context}");
        }
        other => panic!("expected WorkerPanic, got {other:?}"),
    }

    // Subsequent work on the SAME pool is byte-identical to a fresh,
    // fault-free pool.
    let survived = armed.sweep(&net).unwrap().to_json();
    let fresh = SaEngine::builder()
        .max_tiles_per_layer(4)
        .configs(ConfigSet::paper())
        .threads(2)
        .build()
        .unwrap()
        .sweep(&net)
        .unwrap()
        .to_json();
    assert_eq!(survived, fresh, "a contained panic must not perturb later jobs");
}

#[test]
fn error_fault_fails_the_job_with_the_injected_backend_error() {
    let e = builder_with("error@victim:0").build().unwrap();
    let h = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap();
    match h.wait() {
        Err(EngineError::Backend { backend, .. }) => {
            assert_eq!(backend, "fault-inject");
        }
        other => panic!("expected injected Backend error, got {other:?}"),
    }
    // Jobs not matching the fault site are untouched.
    let clean = Layer::gemm_layer("clean", 32, 16, 32, false);
    assert!(e.submit(LayerJob::synthetic(clean, 1)).unwrap().wait().is_ok());
}

#[test]
fn partial_policy_delivers_the_priced_tiles_and_records_the_faults() {
    let e = builder_with("error@victim:1")
        .tile_failure(TileFailurePolicy::Partial)
        .build()
        .unwrap();
    let rep = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap().wait()
        .expect("Partial policy still delivers a report");
    assert_eq!(rep.faults.len(), 1, "exactly the injected fault");
    assert_eq!(rep.faults[0].item, 1);
    assert!(matches!(
        rep.faults[0].error,
        EngineError::Backend { ref backend, .. } if backend == "fault-inject"
    ));
    // The partial report serializes its fault trail.
    let json = rep.to_json();
    assert!(json.contains("\"faults\""), "{json}");
    assert!(json.contains("fault-inject"), "{json}");
    // A clean run of the same layer carries no faults key at all.
    let clean = builder_with("error@other:0").build().unwrap();
    let rep = clean.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap().wait().unwrap();
    assert!(rep.faults.is_empty());
    assert!(!rep.to_json().contains("\"faults\""));
}

#[test]
fn worker_stage_panic_kills_the_thread_and_the_pool_respawns_it() {
    // `@worker` fires OUTSIDE the per-item containment: the worker
    // thread genuinely dies, the item is still accounted (no hang), the
    // pool replaces the thread and keeps serving.
    let e = builder_with("panic@victim:0@worker").build().unwrap();
    assert_eq!(e.respawned_workers(), 0);
    let h = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap();
    match h.wait() {
        Err(EngineError::WorkerPanic { .. }) => {}
        other => panic!("expected WorkerPanic, got {other:?}"),
    }
    let clean = Layer::gemm_layer("clean", 32, 16, 32, false);
    assert!(e.submit(LayerJob::synthetic(clean, 1)).unwrap().wait().is_ok());
    assert!(
        e.respawned_workers() >= 1,
        "the dead worker must be replaced, got {}",
        e.respawned_workers()
    );
}

// ---- deadlines, cancellation ----------------------------------------

#[test]
fn deadline_converts_a_wedged_job_into_timeout() {
    let e = builder_with("delay:400@victim:0").build().unwrap();
    let t0 = Instant::now();
    let h = e
        .submit_with_timeout(
            LayerJob::synthetic(victim_layer(), 0),
            Some(Duration::from_millis(60)),
        )
        .unwrap();
    match h.wait() {
        Err(EngineError::Timeout { limit }) => {
            assert_eq!(limit, Duration::from_millis(60));
        }
        other => panic!("expected Timeout, got {other:?}"),
    }
    // wait() resolves at the deadline, not after the injected 400 ms.
    assert!(t0.elapsed() < Duration::from_millis(350));
}

#[test]
fn builder_default_timeout_applies_to_plain_submits() {
    let e = builder_with("delay:400@victim:0")
        .default_timeout(Duration::from_millis(50))
        .build()
        .unwrap();
    let h = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap();
    assert!(matches!(h.wait(), Err(EngineError::Timeout { .. })));
}

#[test]
fn cancelled_jobs_resolve_to_cancelled() {
    let e = builder_with("delay:150@victim:0").build().unwrap();
    let h = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap();
    h.cancel();
    // Best-effort: a job racing to completion may still deliver.
    match h.wait() {
        Err(EngineError::Cancelled) | Ok(_) => {}
        other => panic!("expected Cancelled or a raced report, got {other:?}"),
    }
    // The pool is unaffected.
    let clean = Layer::gemm_layer("clean", 32, 16, 32, false);
    assert!(e.submit(LayerJob::synthetic(clean, 1)).unwrap().wait().is_ok());
}

// ---- bounded admission ----------------------------------------------

#[test]
fn reject_policy_fails_fast_at_queue_depth() {
    let e = builder_with("delay:150@*:0")
        .threads(1)
        .queue_capacity(1)
        .admission(AdmissionPolicy::Reject)
        .build()
        .unwrap();
    let first = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap();
    match e.submit(LayerJob::synthetic(victim_layer(), 1)) {
        Err(EngineError::QueueFull { capacity }) => assert_eq!(capacity, 1),
        other => panic!("expected QueueFull at depth, got {other:?}"),
    }
    assert!(first.wait().is_ok());
    // The slot freed on delivery: admission works again.
    assert!(e.submit(LayerJob::synthetic(victim_layer(), 2)).unwrap().wait().is_ok());
}

#[test]
fn block_policy_applies_backpressure_until_a_slot_frees() {
    let e = std::sync::Arc::new(
        builder_with("delay:150@*:0")
            .threads(1)
            .queue_capacity(1)
            .admission(AdmissionPolicy::Block)
            .build()
            .unwrap(),
    );
    let first = e.submit(LayerJob::synthetic(victim_layer(), 0)).unwrap();
    let t0 = Instant::now();
    let e2 = std::sync::Arc::clone(&e);
    let blocked = std::thread::spawn(move || {
        let h = e2.submit(LayerJob::synthetic(victim_layer(), 1)).unwrap();
        (Instant::now(), h.wait())
    });
    assert!(first.wait().is_ok());
    let (admitted_at, second) = blocked.join().unwrap();
    assert!(second.is_ok());
    // The second submit could not pass admission before the first job's
    // injected 150 ms delay resolved and delivered.
    assert!(
        admitted_at.duration_since(t0) >= Duration::from_millis(100),
        "blocked submit admitted after {:?}",
        admitted_at.duration_since(t0)
    );
}

#[test]
fn drain_completes_every_admitted_job() {
    let e = builder_with("delay:60@*:0").threads(2).build().unwrap();
    let handles: Vec<_> = (0..4)
        .map(|i| e.submit(LayerJob::synthetic(victim_layer(), i)).unwrap())
        .collect();
    e.drain();
    for h in handles {
        assert!(h.wait().is_ok(), "admitted jobs must complete across drain");
    }
}

// ---- caller errors are rejected at the boundary ---------------------

#[test]
fn builder_rejects_degenerate_pool_specs() {
    for (builder, what) in [
        (SaEngine::builder().threads(0), "zero threads"),
        (SaEngine::builder().threads(MAX_THREADS + 1), "absurd thread count"),
        (SaEngine::builder().queue_capacity(0), "zero-capacity queue"),
    ] {
        match builder.build() {
            Err(EngineError::InvalidSpec(_)) => {}
            other => panic!("{what} must be InvalidSpec, got {other:?}"),
        }
    }
}

#[test]
fn submit_rejects_invalid_workloads_before_admission() {
    let e = SaEngine::builder().threads(1).build().unwrap();
    // zero-stride conv would divide by zero in lowering
    let mut conv = Layer::conv("bad-stride", 3, 4, 4, 1, 8, true);
    conv.stride = 0;
    assert!(matches!(
        e.submit(LayerJob::synthetic(conv, 0)),
        Err(EngineError::InvalidWorkload(_))
    ));
    // tensor lengths must match the layer's lowering
    let g = Layer::gemm_layer("g", 4, 4, 4, false);
    assert!(matches!(
        e.submit(LayerJob::with_data(g.clone(), 0, vec![0.0; 16], vec![0.0; 5])),
        Err(EngineError::InvalidWorkload(_))
    ));
    // a rejected submit holds no admission slot
    assert_eq!(e.pending_jobs(), 0);
    // and a well-formed job still runs
    assert!(e
        .submit(LayerJob::with_data(g, 0, vec![0.5; 16], vec![0.25; 16]))
        .unwrap()
        .wait()
        .is_ok());
}

// ---- typed errors carry stable operational metadata ------------------

#[test]
fn error_kinds_and_exit_codes_are_stable() {
    let cases: Vec<(EngineError, &str, i32)> = vec![
        (EngineError::InvalidSpec("x".into()), "invalid-spec", 2),
        (EngineError::InvalidWorkload("x".into()), "invalid-workload", 3),
        (
            EngineError::Backend { backend: "b".into(), message: "m".into() },
            "backend",
            4,
        ),
        (
            EngineError::WorkerPanic { context: "c".into(), message: "m".into() },
            "worker-panic",
            5,
        ),
        (EngineError::PoolShutdown, "pool-shutdown", 6),
        (EngineError::Timeout { limit: Duration::from_secs(1) }, "timeout", 7),
        (EngineError::Cancelled, "cancelled", 8),
        (EngineError::QueueFull { capacity: 4 }, "queue-full", 9),
        (EngineError::Internal("x".into()), "internal", 10),
    ];
    for (e, kind, code) in cases {
        assert_eq!(e.kind(), kind, "{e}");
        assert_eq!(e.exit_code(), code, "{e}");
    }
}

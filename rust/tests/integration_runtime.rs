//! Integration: the rust runtime against the real AOT artifacts.
//!
//! These tests exercise the full L1/L2/L3 bridge: JAX+Pallas graphs,
//! lowered to HLO text at build time, executed from rust via PJRT — and
//! cross-checked against the in-tree rust implementations (bf16 matmul,
//! weight statistics, switching-activity counting).
//!
//! They require `make artifacts`; without it they are skipped with a
//! message (the Makefile test target guarantees artifacts exist).

use std::path::PathBuf;

use sa_lowpower::activity::stream_toggles;
use sa_lowpower::bf16::{matmul_f32acc, Bf16};
use sa_lowpower::runtime::Runtime;
use sa_lowpower::stats::WeightFieldStats;
use sa_lowpower::util::Rng64;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

macro_rules! require_artifacts {
    () => {
        match artifact_dir() {
            Some(d) => d,
            None => return,
        }
    };
}

#[test]
fn manifest_covers_all_artifacts_and_files_exist() {
    let dir = require_artifacts!();
    let rt = Runtime::open(&dir).unwrap();
    let names: Vec<&str> = rt.manifest().names().collect();
    for want in [
        "tinycnn_forward",
        "gemm_256",
        "gemm_zero_skip_256",
        "weight_stats",
        "activity_stats",
    ] {
        assert!(names.contains(&want), "missing artifact {want}");
        assert!(rt.manifest().get(want).unwrap().file.exists());
    }
}

#[test]
fn gemm_artifact_matches_rust_bf16_matmul() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(&dir).unwrap();
    let mut rng = Rng64::new(1);
    let n = 256;
    let a: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..n * n).map(|_| (rng.normal() * 0.1) as f32).collect();

    let out = rt.run("gemm_256", &[&a, &b]).unwrap();
    let got = out[0].as_f32().unwrap();

    let a16: Vec<Bf16> = a.iter().map(|&x| Bf16::from_f32(x)).collect();
    let b16: Vec<Bf16> = b.iter().map(|&x| Bf16::from_f32(x)).collect();
    let want = matmul_f32acc(&a16, &b16, n, n, n);

    // identical bf16 quantization; accumulation order differs (Pallas
    // K-blocks vs row-major) -> tiny f32 rounding differences only
    let mut max_rel = 0f64;
    for (g, w) in got.iter().zip(&want) {
        // mixed tolerance: K=256 f32 accumulations in different orders
        // (Pallas K-blocks vs row-major) + cancellation on small outputs
        let rel = ((g - w).abs() as f64) / (w.abs() as f64 + 0.1);
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-3, "max rel err {max_rel}");
}

#[test]
fn zero_skip_gemm_is_bit_identical_to_plain_gemm() {
    // The Pallas kernel's zero-block skipping (the L1 analogue of ZVCG)
    // must be a pure power optimization.
    let dir = require_artifacts!();
    let mut rt = Runtime::open(&dir).unwrap();
    let mut rng = Rng64::new(2);
    let n = 256;
    let mut a: Vec<f32> = (0..n * n)
        .map(|_| if rng.chance(0.5) { 0.0 } else { rng.normal() as f32 })
        .collect();
    // make whole 16-row blocks zero to exercise the block-skip path
    for r in 64..96 {
        for c in 0..n {
            a[r * n + c] = 0.0;
        }
    }
    let b: Vec<f32> = (0..n * n).map(|_| rng.normal() as f32).collect();
    let plain = rt.run("gemm_256", &[&a, &b]).unwrap();
    let skip = rt.run("gemm_zero_skip_256", &[&a, &b]).unwrap();
    assert_eq!(plain[0].as_f32().unwrap(), skip[0].as_f32().unwrap());
}

#[test]
fn weight_stats_artifact_matches_rust_stats() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(&dir).unwrap();
    let mut rng = Rng64::new(3);
    let w: Vec<f32> = (0..16384)
        .map(|_| ((rng.normal() * 0.08) as f32).clamp(-1.0, 1.0))
        .collect();
    let out = rt.run("weight_stats", &[&w]).unwrap();
    let exp_hist = out[0].as_i32().unwrap();
    let man_hist = out[1].as_i32().unwrap();
    let zeros = out[2].as_i32().unwrap()[0];
    let total = out[3].as_i32().unwrap()[0];

    let s = WeightFieldStats::from_f32(&w);
    assert_eq!(total as u64, s.total);
    assert_eq!(zeros as u64, s.zeros);
    // python counts zero values in the exponent-0 bin too; rust excludes
    // them from the field histograms. Compare with that correction.
    let mut exp_want: Vec<i64> = s.exp_hist.iter().map(|&c| c as i64).collect();
    exp_want[0] += s.zeros as i64;
    let mut man_want: Vec<i64> = s.man_hist.iter().map(|&c| c as i64).collect();
    man_want[0] += s.zeros as i64;
    assert_eq!(
        exp_hist.iter().map(|&c| c as i64).collect::<Vec<_>>(),
        exp_want
    );
    assert_eq!(
        man_hist.iter().map(|&c| c as i64).collect::<Vec<_>>(),
        man_want
    );
}

#[test]
fn activity_artifact_matches_rust_toggle_counting() {
    // The L1 Pallas activity kernel and the rust activity substrate must
    // count the exact same toggles/zeros.
    let dir = require_artifacts!();
    let mut rt = Runtime::open(&dir).unwrap();
    let mut rng = Rng64::new(4);
    let (lanes, len) = (16, 1024);
    let s: Vec<f32> = (0..lanes * len)
        .map(|_| if rng.chance(0.4) { 0.0 } else { rng.normal() as f32 })
        .collect();
    let out = rt.run("activity_stats", &[&s]).unwrap();
    let toggles = out[0].as_i32().unwrap();
    let zeros = out[1].as_i32().unwrap();

    for lane in 0..lanes {
        let row: Vec<Bf16> = s[lane * len..(lane + 1) * len]
            .iter()
            .map(|&x| Bf16::from_f32(x))
            .collect();
        // kernel counts transitions *within* the lane (no reset state):
        // subtract the reset->first transition from the rust count.
        let with_reset = stream_toggles(Bf16::ZERO, &row);
        let first = row[0].0.count_ones() as u64;
        assert_eq!(toggles[lane] as u64, with_reset - first, "lane {lane}");
        let z = row.iter().filter(|v| v.is_zero()).count();
        assert_eq!(zeros[lane] as usize, z, "lane {lane}");
    }
}

#[test]
fn executable_cache_compiles_once() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(&dir).unwrap();
    assert_eq!(rt.cached(), 0);
    rt.load("gemm_256").unwrap();
    assert_eq!(rt.cached(), 1);
    rt.load("gemm_256").unwrap();
    assert_eq!(rt.cached(), 1);
    rt.load("weight_stats").unwrap();
    assert_eq!(rt.cached(), 2);
}

#[test]
fn shape_validation_rejects_bad_inputs() {
    let dir = require_artifacts!();
    let mut rt = Runtime::open(&dir).unwrap();
    let short = vec![0f32; 3];
    assert!(rt.run("gemm_256", &[&short, &short]).is_err());
    let ok = vec![0f32; 256 * 256];
    assert!(rt.run("gemm_256", &[&ok]).is_err(), "wrong arity");
}

//! Bit-exact migration contract for the codec-stack redesign.
//!
//! This file contains a **frozen copy** of the pre-stack reference
//! simulator (both dataflows), exactly as it pattern-matched on
//! `SaCodingConfig`'s `BicMode` fields and ZVCG booleans before the
//! `StreamCodec`/`CodingStack` migration. The tests assert that, for
//! every registry named config (plus the policy/input-side/weight-gating
//! extras) × {ws, os} × {analytic, cycle} backend, the new codec-stack
//! path reproduces the legacy `ActivityCounts` AND the f32 outputs
//! exactly — shim-lowered stacks (`SaCodingConfig::stack()`) against
//! yesterday's engine, integer for integer, bit for bit.
//!
//! Do not "fix" or modernise the legacy copy: its whole value is that it
//! does not move. (The two post-migration ledger fields,
//! `west/north_comparator_bit_cycles`, default to 0 here — pre-stack
//! designs never charge them, which is itself part of the contract.)

use sa_lowpower::activity::{ham1, ham16_masked, ham_bf16, ActivityCounts};
use sa_lowpower::bf16::Bf16;
use sa_lowpower::coding::{
    decode, BicEncoder, BicMode, BicPolicy, Encoded, SaCodingConfig,
};
use sa_lowpower::engine::{
    AnalyticBackend, ConfigRegistry, CycleBackend, EstimatorBackend,
};
use sa_lowpower::sa::{simulate_tile, simulate_tile_reference, Dataflow, Tile};
use sa_lowpower::util::prop::check;
use sa_lowpower::util::Rng64;

// =====================================================================
// Frozen legacy reference simulator (pre-stack, verbatim semantics)
// =====================================================================

#[derive(Clone, Copy, Debug)]
struct EdgeSlot {
    gated: bool,
    data: Bf16,
    inv: u8,
}

fn legacy_edge_stream(
    raw: &[Bf16],
    zvcg: bool,
    bic: BicMode,
    policy: BicPolicy,
    counts: &mut ActivityCounts,
) -> Vec<EdgeSlot> {
    let mut enc = BicEncoder::new(bic, policy);
    raw.iter()
        .map(|&v| {
            if zvcg {
                counts.zero_detect_ops += 1;
            }
            if zvcg && v.is_zero() {
                return EdgeSlot { gated: true, data: Bf16::ZERO, inv: 0 };
            }
            let e: Encoded = if bic != BicMode::None {
                counts.encoder_ops += 1;
                enc.encode(v)
            } else {
                Encoded { tx: v, inv: 0 }
            };
            EdgeSlot { gated: false, data: e.tx, inv: e.inv }
        })
        .collect()
}

fn legacy_edge_streams(
    tile: &Tile,
    cfg: &SaCodingConfig,
    counts: &mut ActivityCounts,
) -> (Vec<Vec<EdgeSlot>>, Vec<Vec<EdgeSlot>>) {
    let west = (0..tile.m)
        .map(|i| {
            legacy_edge_stream(
                tile.a_row(i),
                cfg.input_zvcg,
                cfg.input_bic,
                cfg.bic_policy,
                counts,
            )
        })
        .collect();
    let north = (0..tile.n)
        .map(|j| {
            legacy_edge_stream(
                tile.b_col(j),
                cfg.weight_zvcg,
                cfg.weight_bic,
                cfg.bic_policy,
                counts,
            )
        })
        .collect();
    (west, north)
}

#[derive(Clone, Copy, Debug, Default)]
struct Stage {
    data: Bf16,
    zero: bool,
    inv: u8,
}

fn bic_cover_mask(mode: BicMode) -> u16 {
    mode.segments().iter().fold(0u16, |acc, &m| acc | m)
}

struct LegacyResult {
    counts: ActivityCounts,
    c: Vec<f32>,
}

fn legacy_reference(
    tile: &Tile,
    cfg: &SaCodingConfig,
    dataflow: Dataflow,
) -> LegacyResult {
    match dataflow {
        Dataflow::WeightStationary => legacy_ws_reference(tile, cfg),
        Dataflow::OutputStationary => legacy_os_reference(tile, cfg),
    }
}

fn legacy_ws_reference(tile: &Tile, cfg: &SaCodingConfig) -> LegacyResult {
    let (m, k, n) = (tile.m, tile.k, tile.n);
    let mut counts = ActivityCounts::default();
    let (west, north) = legacy_edge_streams(tile, cfg, &mut counts);

    let mut a_st = vec![Stage::default(); m * n];
    let mut b_st = vec![Stage::default(); m * n];
    let mut mlat_a = vec![Bf16::ZERO; m * n];
    let mut mlat_b = vec![Bf16::ZERO; m * n];
    let mut acc = vec![0f32; m * n];

    let idx = |i: usize, j: usize| i * n + j;
    let total_cycles = (k + m + n) as i64;

    for c in 0..total_cycles {
        for i in 0..m {
            for j in 0..n {
                let kk = c - 1 - i as i64 - j as i64;
                if kk < 0 || kk >= k as i64 {
                    continue;
                }
                let p = idx(i, j);
                if cfg.input_zvcg || cfg.weight_zvcg {
                    counts.acc_cg_cell_cycles += 1;
                }
                let gated = a_st[p].zero || b_st[p].zero;
                if gated {
                    counts.gated_macs += 1;
                    continue;
                }
                let a = decode(
                    cfg.input_bic,
                    Encoded { tx: a_st[p].data, inv: a_st[p].inv },
                );
                let b = decode(
                    cfg.weight_bic,
                    Encoded { tx: b_st[p].data, inv: b_st[p].inv },
                );
                counts.mult_input_toggles +=
                    (ham_bf16(mlat_a[p], a) + ham_bf16(mlat_b[p], b)) as u64;
                mlat_a[p] = a;
                mlat_b[p] = b;
                counts.acc_clock_events += 32;
                if a.is_zero() || b.is_zero() {
                    counts.zero_product_macs += 1;
                } else {
                    counts.active_macs += 1;
                    acc[p] += a.to_f32() * b.to_f32();
                }
            }
        }

        for i in 0..m {
            for j in (0..n).rev() {
                let kk = c - i as i64 - j as i64;
                if kk < 0 || kk >= k as i64 {
                    continue;
                }
                let p = idx(i, j);
                let incoming = if j == 0 {
                    let s = west[i][kk as usize];
                    Stage { data: s.data, zero: s.gated, inv: s.inv }
                } else {
                    a_st[idx(i, j - 1)]
                };
                if cfg.input_zvcg {
                    counts.west_sideband_toggles +=
                        ham1(a_st[p].zero, incoming.zero) as u64;
                    counts.west_sideband_clock_events += 1;
                    counts.west_cg_cell_cycles += 1;
                }
                let gate = cfg.input_zvcg && incoming.zero;
                if gate {
                    a_st[p].zero = true;
                } else {
                    counts.west_data_toggles +=
                        ham_bf16(a_st[p].data, incoming.data) as u64;
                    counts.west_clock_events += 16;
                    if cfg.input_bic != BicMode::None {
                        let lines = cfg.input_bic.inv_lines() as u64;
                        counts.decoder_toggles += ham16_masked(
                            a_st[p].data.0,
                            incoming.data.0,
                            bic_cover_mask(cfg.input_bic),
                        )
                            as u64
                            + (a_st[p].inv ^ incoming.inv).count_ones() as u64;
                        counts.west_sideband_toggles +=
                            (a_st[p].inv ^ incoming.inv).count_ones() as u64;
                        counts.west_sideband_clock_events += lines;
                    }
                    a_st[p].data = incoming.data;
                    a_st[p].inv = incoming.inv;
                    a_st[p].zero = false;
                }
            }
        }

        for j in 0..n {
            for i in (0..m).rev() {
                let kk = c - i as i64 - j as i64;
                if kk < 0 || kk >= k as i64 {
                    continue;
                }
                let p = idx(i, j);
                let incoming = if i == 0 {
                    let s = north[j][kk as usize];
                    Stage { data: s.data, zero: s.gated, inv: s.inv }
                } else {
                    b_st[idx(i - 1, j)]
                };
                if cfg.weight_zvcg {
                    counts.north_sideband_toggles +=
                        ham1(b_st[p].zero, incoming.zero) as u64;
                    counts.north_sideband_clock_events += 1;
                    counts.north_cg_cell_cycles += 1;
                }
                let gate = cfg.weight_zvcg && incoming.zero;
                if gate {
                    b_st[p].zero = true;
                } else {
                    counts.north_data_toggles +=
                        ham_bf16(b_st[p].data, incoming.data) as u64;
                    counts.north_clock_events += 16;
                    if cfg.weight_bic != BicMode::None {
                        let lines = cfg.weight_bic.inv_lines() as u64;
                        counts.decoder_toggles += ham16_masked(
                            b_st[p].data.0,
                            incoming.data.0,
                            bic_cover_mask(cfg.weight_bic),
                        )
                            as u64
                            + (b_st[p].inv ^ incoming.inv).count_ones() as u64;
                        counts.north_sideband_toggles +=
                            (b_st[p].inv ^ incoming.inv).count_ones() as u64;
                        counts.north_sideband_clock_events += lines;
                    }
                    b_st[p].data = incoming.data;
                    b_st[p].inv = incoming.inv;
                    b_st[p].zero = false;
                }
            }
        }
    }

    counts.unload_values += (m * n) as u64;
    counts.cycles += total_cycles as u64;
    LegacyResult { counts, c: acc }
}

fn legacy_os_reference(tile: &Tile, cfg: &SaCodingConfig) -> LegacyResult {
    let (m, k, n) = (tile.m, tile.k, tile.n);
    let mut counts = ActivityCounts::default();
    let (west, north) = legacy_edge_streams(tile, cfg, &mut counts);

    let mut a_reg = vec![Stage::default(); m];
    let mut b_reg = vec![Stage::default(); n];
    let mut mlat_a = vec![Bf16::ZERO; m * n];
    let mut mlat_b = vec![Bf16::ZERO; m * n];
    let mut acc = vec![0f32; m * n];

    let total_cycles = k + 1;
    for c in 0..total_cycles {
        if c >= 1 {
            for i in 0..m {
                for j in 0..n {
                    if cfg.input_zvcg || cfg.weight_zvcg {
                        counts.acc_cg_cell_cycles += 1;
                    }
                    if a_reg[i].zero || b_reg[j].zero {
                        counts.gated_macs += 1;
                        continue;
                    }
                    let a = decode(
                        cfg.input_bic,
                        Encoded { tx: a_reg[i].data, inv: a_reg[i].inv },
                    );
                    let b = decode(
                        cfg.weight_bic,
                        Encoded { tx: b_reg[j].data, inv: b_reg[j].inv },
                    );
                    let p = i * n + j;
                    counts.mult_input_toggles +=
                        (ham_bf16(mlat_a[p], a) + ham_bf16(mlat_b[p], b)) as u64;
                    mlat_a[p] = a;
                    mlat_b[p] = b;
                    counts.acc_clock_events += 32;
                    if a.is_zero() || b.is_zero() {
                        counts.zero_product_macs += 1;
                    } else {
                        counts.active_macs += 1;
                        acc[p] += a.to_f32() * b.to_f32();
                    }
                }
            }
        }

        if c < k {
            for i in 0..m {
                let s = west[i][c];
                if cfg.input_zvcg {
                    counts.west_sideband_toggles +=
                        ham1(a_reg[i].zero, s.gated) as u64;
                    counts.west_sideband_clock_events += 1;
                    counts.west_cg_cell_cycles += 1;
                }
                if cfg.input_zvcg && s.gated {
                    a_reg[i].zero = true;
                } else {
                    counts.west_data_toggles +=
                        ham_bf16(a_reg[i].data, s.data) as u64;
                    counts.west_clock_events += 16;
                    if cfg.input_bic != BicMode::None {
                        let inv_diff =
                            (a_reg[i].inv ^ s.inv).count_ones() as u64;
                        counts.decoder_toggles += n as u64
                            * (ham16_masked(
                                a_reg[i].data.0,
                                s.data.0,
                                bic_cover_mask(cfg.input_bic),
                            ) as u64
                                + inv_diff);
                        counts.west_sideband_toggles += inv_diff;
                        counts.west_sideband_clock_events +=
                            cfg.input_bic.inv_lines() as u64;
                    }
                    a_reg[i] = Stage { data: s.data, zero: false, inv: s.inv };
                }
            }
            for j in 0..n {
                let s = north[j][c];
                if cfg.weight_zvcg {
                    counts.north_sideband_toggles +=
                        ham1(b_reg[j].zero, s.gated) as u64;
                    counts.north_sideband_clock_events += 1;
                    counts.north_cg_cell_cycles += 1;
                }
                if cfg.weight_zvcg && s.gated {
                    b_reg[j].zero = true;
                } else {
                    counts.north_data_toggles +=
                        ham_bf16(b_reg[j].data, s.data) as u64;
                    counts.north_clock_events += 16;
                    if cfg.weight_bic != BicMode::None {
                        let inv_diff =
                            (b_reg[j].inv ^ s.inv).count_ones() as u64;
                        counts.decoder_toggles += m as u64
                            * (ham16_masked(
                                b_reg[j].data.0,
                                s.data.0,
                                bic_cover_mask(cfg.weight_bic),
                            ) as u64
                                + inv_diff);
                        counts.north_sideband_toggles += inv_diff;
                        counts.north_sideband_clock_events +=
                            cfg.weight_bic.inv_lines() as u64;
                    }
                    b_reg[j] = Stage { data: s.data, zero: false, inv: s.inv };
                }
            }
        }
    }

    counts.unload_values += (m * n) as u64;
    counts.cycles += total_cycles as u64;
    LegacyResult { counts, c: acc }
}

// =====================================================================
// The migration contract
// =====================================================================

fn random_tile(
    rng: &mut Rng64,
    m: usize,
    k: usize,
    n: usize,
    pz_a: f64,
    pz_b: f64,
) -> Tile {
    let a: Vec<f32> = (0..m * k)
        .map(|_| if rng.chance(pz_a) { 0.0 } else { rng.normal() as f32 })
        .collect();
    let b: Vec<f32> = (0..k * n)
        .map(|_| if rng.chance(pz_b) { 0.0 } else { (rng.normal() * 0.1) as f32 })
        .collect();
    Tile::from_f32(&a, &b, m, k, n)
}

/// Every closed-struct design the legacy engine could express: the
/// registry's legacy rows plus the policy / input-BIC / weight-gating
/// extras the old property suite covered.
fn legacy_configs() -> Vec<(String, SaCodingConfig)> {
    let mut v: Vec<(String, SaCodingConfig)> = ConfigRegistry::entries()
        .iter()
        .filter_map(|e| e.legacy.map(|c| (e.name.to_string(), c)))
        .collect();
    v.push((
        "proposed+w-zvcg".into(),
        SaCodingConfig { weight_zvcg: true, ..SaCodingConfig::proposed() },
    ));
    v.push((
        "input-bic".into(),
        SaCodingConfig {
            input_bic: BicMode::MantissaOnly,
            ..SaCodingConfig::baseline()
        },
    ));
    v.push((
        "input-zvcg+bic".into(),
        SaCodingConfig {
            input_bic: BicMode::Segmented,
            ..SaCodingConfig::proposed()
        },
    ));
    v.push((
        "proposed-mt".into(),
        SaCodingConfig {
            bic_policy: BicPolicy::MinTransitions,
            ..SaCodingConfig::proposed()
        },
    ));
    v
}

const BOTH: [Dataflow; 2] =
    [Dataflow::WeightStationary, Dataflow::OutputStationary];

#[test]
fn stack_engines_reproduce_legacy_counts_and_outputs() {
    check("new stack path == frozen legacy reference", 12, |rng| {
        let (m, k, n) = (1 + rng.below(7), 1 + rng.below(18), 1 + rng.below(7));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for (name, cfg) in legacy_configs() {
            let stack = cfg.stack();
            for df in BOTH {
                let legacy = legacy_reference(&t, &cfg, df);
                let reference = simulate_tile_reference(&t, &stack, df);
                assert_eq!(
                    reference.counts, legacy.counts,
                    "reference counts drifted: '{name}' {df} {m}x{k}x{n}"
                );
                assert_eq!(
                    reference.c, legacy.c,
                    "reference outputs drifted: '{name}' {df}"
                );
                let fast = simulate_tile(&t, &stack, df);
                assert_eq!(fast.counts, legacy.counts, "fast counts: '{name}' {df}");
                assert_eq!(fast.c, legacy.c, "fast outputs: '{name}' {df}");
                // both estimator backends, per the acceptance criterion
                let a = AnalyticBackend.estimate(&t, &stack, df).unwrap();
                let c = CycleBackend.estimate(&t, &stack, df).unwrap();
                assert_eq!(a, legacy.counts, "analytic backend: '{name}' {df}");
                assert_eq!(c, legacy.counts, "cycle backend: '{name}' {df}");
            }
        }
    });
}

#[test]
fn batched_estimation_reproduces_legacy_counts() {
    // The count-once/price-many path must also hold the migration
    // contract: one shared TileActivity pass priced under every legacy
    // design reproduces the frozen pre-stack reference word-for-word,
    // on both backends.
    check("estimate_many == frozen legacy reference", 8, |rng| {
        let (m, k, n) = (1 + rng.below(6), 1 + rng.below(16), 1 + rng.below(6));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let cfgs = legacy_configs();
        let stacks: Vec<_> = cfgs.iter().map(|(_, c)| c.stack()).collect();
        for df in BOTH {
            let a = AnalyticBackend.estimate_many(&t, &stacks, df).unwrap();
            let c = CycleBackend.estimate_many(&t, &stacks, df).unwrap();
            for (i, (name, cfg)) in cfgs.iter().enumerate() {
                let legacy = legacy_reference(&t, cfg, df);
                assert_eq!(a[i], legacy.counts, "analytic batched: '{name}' {df}");
                assert_eq!(c[i], legacy.counts, "cycle batched: '{name}' {df}");
            }
        }
    });
}

#[test]
fn stack_engines_reproduce_legacy_on_degenerate_tiles() {
    let mut rng = Rng64::new(0x1EA5);
    let tiles = vec![
        random_tile(&mut rng, 1, 1, 1, 0.3, 0.1),
        Tile::from_f32(&[0.0; 3 * 8], &[0.5; 8 * 4], 3, 8, 4),
        Tile::from_f32(&[0.25; 3 * 8], &[0.0; 8 * 4], 3, 8, 4),
        random_tile(&mut rng, 7, 1, 1, 0.5, 0.5),
        random_tile(&mut rng, 1, 64, 1, 0.6, 0.2),
    ];
    for t in &tiles {
        for (name, cfg) in legacy_configs() {
            let stack = cfg.stack();
            for df in BOTH {
                let legacy = legacy_reference(t, &cfg, df);
                let fast = simulate_tile(t, &stack, df);
                assert_eq!(
                    fast.counts, legacy.counts,
                    "'{name}' {df} {}x{}x{}",
                    t.m, t.k, t.n
                );
                assert_eq!(fast.c, legacy.c, "'{name}' {df}");
                assert_eq!(
                    AnalyticBackend.estimate(t, &stack, df).unwrap(),
                    legacy.counts,
                    "'{name}' {df}"
                );
            }
        }
    }
}

#[test]
fn legacy_designs_never_charge_the_new_ledger_fields() {
    // pre-stack designs have no register clock gating: the comparator
    // fields the v3 ledger added must stay zero through the shim
    let mut rng = Rng64::new(77);
    let t = random_tile(&mut rng, 5, 12, 5, 0.4, 0.2);
    for (name, cfg) in legacy_configs() {
        for df in BOTH {
            let c = AnalyticBackend.estimate(&t, &cfg.stack(), df).unwrap();
            assert_eq!(c.west_comparator_bit_cycles, 0, "'{name}' {df}");
            assert_eq!(c.north_comparator_bit_cycles, 0, "'{name}' {df}");
        }
    }
}

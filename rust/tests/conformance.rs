//! Differential conformance suite — the bit-exactness contract of the
//! dataflow × backend matrix (see `engine/backend.rs` for the contract
//! text). Any new engine (a third dataflow, an alternative estimator)
//! must pass this suite before it ships:
//!
//! (a) **functional**: weight-stationary and output-stationary produce
//!     bit-identical f32 GEMM outputs on every tile, under every
//!     registry config;
//! (b) **intra-dataflow**: the fast `simulate_tile` equals the literal
//!     `simulate_tile_reference` — counts and outputs — per dataflow;
//! (c) **inter-backend**: the analytic model and the cycle simulator
//!     agree on the entire activity ledger per dataflow, and the
//!     MAC-side counts are additionally invariant *across* dataflows;
//!
//! including degenerate geometries (1×1 tiles, all-zero operands) and
//! the zero-K rejection at the `Tile` boundary.

use sa_lowpower::engine::{
    AnalyticBackend, BackendKind, ConfigSet, CycleBackend, EngineError,
    EstimatorBackend, FaultPlan, LayerJob, SaEngine, SweepDoc,
};
use sa_lowpower::sa::{
    analyze_tile, simulate_tile, simulate_tile_reference, Dataflow, Tile,
};
use sa_lowpower::util::prop::check;
use sa_lowpower::util::Rng64;
use sa_lowpower::workload::Network;

const WS: Dataflow = Dataflow::WeightStationary;
const OS: Dataflow = Dataflow::OutputStationary;

fn random_tile(
    rng: &mut Rng64,
    m: usize,
    k: usize,
    n: usize,
    pz_a: f64,
    pz_b: f64,
) -> Tile {
    let a: Vec<f32> = (0..m * k)
        .map(|_| if rng.chance(pz_a) { 0.0 } else { rng.normal() as f32 })
        .collect();
    let b: Vec<f32> = (0..k * n)
        .map(|_| if rng.chance(pz_b) { 0.0 } else { (rng.normal() * 0.1) as f32 })
        .collect();
    Tile::from_f32(&a, &b, m, k, n)
}

/// Degenerate tiles every conformance clause must also hold on.
fn degenerate_tiles(rng: &mut Rng64) -> Vec<Tile> {
    vec![
        // 1×1×1: single PE, single slot
        random_tile(rng, 1, 1, 1, 0.3, 0.1),
        // all-zero A (everything gates under input ZVCG)
        Tile::from_f32(&[0.0; 3 * 8], &[0.5; 8 * 4], 3, 8, 4),
        // all-zero B (zero products everywhere; weight-ZVCG gates all)
        Tile::from_f32(&[0.25; 3 * 8], &[0.0; 8 * 4], 3, 8, 4),
        // all-zero both
        Tile::from_f32(&[0.0; 2 * 5], &[0.0; 5 * 2], 2, 5, 2),
        // K=1 stream, skinny arrays
        random_tile(rng, 7, 1, 1, 0.5, 0.5),
        random_tile(rng, 1, 1, 7, 0.5, 0.5),
    ]
}

// ---- (a) cross-dataflow functional equality --------------------------

#[test]
fn ws_and_os_outputs_bit_identical() {
    check("C(ws) == C(os) bit-for-bit, all registry configs", 15, |rng| {
        let (m, k, n) = (1 + rng.below(10), 1 + rng.below(24), 1 + rng.below(10));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let want = t.reference_result();
        for (name, cfg) in ConfigSet::all().iter() {
            let ws = simulate_tile(&t, cfg, WS);
            let os = simulate_tile(&t, cfg, OS);
            assert_eq!(ws.c, os.c, "'{name}' {m}x{k}x{n}");
            assert_eq!(ws.c, want, "'{name}' vs f32 reference");
        }
    });
}

#[test]
fn ws_and_os_outputs_bit_identical_on_degenerate_tiles() {
    let mut rng = Rng64::new(0xC0FF);
    for t in degenerate_tiles(&mut rng) {
        for (name, cfg) in ConfigSet::all().iter() {
            let ws = simulate_tile(&t, cfg, WS);
            let os = simulate_tile(&t, cfg, OS);
            assert_eq!(ws.c, os.c, "'{name}' {}x{}x{}", t.m, t.k, t.n);
            assert_eq!(ws.c, t.reference_result(), "'{name}'");
        }
    }
}

// ---- (b) fast engine == literal reference, per dataflow --------------

#[test]
fn fast_equals_reference_counts_per_dataflow() {
    check("simulate_tile == simulate_tile_reference", 10, |rng| {
        let (m, k, n) = (1 + rng.below(9), 1 + rng.below(20), 1 + rng.below(9));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for (name, cfg) in ConfigSet::all().iter() {
            for df in [WS, OS] {
                let fast = simulate_tile(&t, cfg, df);
                let golden = simulate_tile_reference(&t, cfg, df);
                assert_eq!(fast.counts, golden.counts, "'{name}' {df}");
                assert_eq!(fast.c, golden.c, "'{name}' {df}");
            }
        }
    });
}

#[test]
fn fast_equals_reference_on_degenerate_tiles() {
    let mut rng = Rng64::new(0xD00D);
    for t in degenerate_tiles(&mut rng) {
        for (name, cfg) in ConfigSet::all().iter() {
            for df in [WS, OS] {
                let fast = simulate_tile(&t, cfg, df);
                let golden = simulate_tile_reference(&t, cfg, df);
                assert_eq!(
                    fast.counts, golden.counts,
                    "'{name}' {df} {}x{}x{}",
                    t.m, t.k, t.n
                );
                assert_eq!(fast.c, golden.c, "'{name}' {df}");
            }
        }
    }
}

// ---- (c) backend agreement, intra- and inter-dataflow ----------------

#[test]
fn analytic_and_cycle_backends_agree_per_dataflow() {
    check("analytic ledger == cycle ledger", 10, |rng| {
        let (m, k, n) = (1 + rng.below(10), 1 + rng.below(28), 1 + rng.below(10));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for (name, cfg) in ConfigSet::all().iter() {
            for df in [WS, OS] {
                let a = AnalyticBackend.estimate(&t, cfg, df).unwrap();
                let c = CycleBackend.estimate(&t, cfg, df).unwrap();
                assert_eq!(a, c, "'{name}' {df} {m}x{k}x{n}");
            }
        }
    });
}

#[test]
fn analytic_and_cycle_backends_agree_on_degenerate_tiles() {
    let mut rng = Rng64::new(0xBEEF);
    for t in degenerate_tiles(&mut rng) {
        for (name, cfg) in ConfigSet::all().iter() {
            for df in [WS, OS] {
                let a = AnalyticBackend.estimate(&t, cfg, df).unwrap();
                let c = CycleBackend.estimate(&t, cfg, df).unwrap();
                assert_eq!(a, c, "'{name}' {df} {}x{}x{}", t.m, t.k, t.n);
            }
        }
    }
}

#[test]
fn mac_side_counts_are_dataflow_invariant() {
    // The cross-dataflow clause of the backend contract: everything the
    // MAC/accumulator side of the ledger counts is identical between WS
    // and OS (the per-PE operand sequences are the same), while the
    // stream side legitimately shrinks by the fanout under OS.
    check("MAC-side ledger invariant across dataflows", 15, |rng| {
        let (m, k, n) = (1 + rng.below(10), 1 + rng.below(24), 1 + rng.below(10));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for (name, cfg) in ConfigSet::all().iter() {
            let ws = analyze_tile(&t, cfg, WS);
            let os = analyze_tile(&t, cfg, OS);
            assert_eq!(ws.mult_input_toggles, os.mult_input_toggles, "'{name}'");
            assert_eq!(ws.active_macs, os.active_macs, "'{name}'");
            assert_eq!(ws.gated_macs, os.gated_macs, "'{name}'");
            assert_eq!(ws.zero_product_macs, os.zero_product_macs, "'{name}'");
            assert_eq!(ws.acc_clock_events, os.acc_clock_events, "'{name}'");
            assert_eq!(ws.acc_cg_cell_cycles, os.acc_cg_cell_cycles, "'{name}'");
            assert_eq!(ws.unload_values, os.unload_values, "'{name}'");
            // edge logic is shared too: same detectors, same encoders
            assert_eq!(ws.zero_detect_ops, os.zero_detect_ops, "'{name}'");
            assert_eq!(ws.encoder_ops, os.encoder_ops, "'{name}'");
            // stream side: OS registers once per lane, never more than WS
            assert!(ws.west_clock_events >= os.west_clock_events, "'{name}'");
            assert!(ws.north_clock_events >= os.north_clock_events, "'{name}'");
        }
    });
}

// ---- composed --coding stacks obey the same contract -----------------

#[test]
fn composed_spec_stacks_pass_the_full_matrix() {
    // Stacks assembled from the spec grammar (not registry rows) must
    // satisfy every clause: fast == reference, analytic == cycle, and
    // bit-identical f32 outputs across dataflows.
    use sa_lowpower::coding::CodingStack;
    let specs = [
        "w:zvcg+bic-full,i:zvcg+bic-mantissa",
        "w:ddcg16-g16,i:ddcg16-g1",
        "w:zvcg+bic-segmented+ddcg16-g4,i:zvcg+ddcg16-g8",
        "i:zvcg+bic-exponent-mt",
    ];
    check("composed stacks conform", 8, |rng| {
        let (m, k, n) = (1 + rng.below(8), 1 + rng.below(20), 1 + rng.below(8));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let want = t.reference_result();
        for spec in specs {
            let stack = CodingStack::parse(spec).unwrap();
            for df in [WS, OS] {
                let fast = simulate_tile(&t, &stack, df);
                let golden = simulate_tile_reference(&t, &stack, df);
                assert_eq!(fast.counts, golden.counts, "'{spec}' {df}");
                assert_eq!(fast.c, golden.c, "'{spec}' {df}");
                assert_eq!(fast.c, want, "'{spec}' {df} vs f32 reference");
                assert_eq!(
                    AnalyticBackend.estimate(&t, &stack, df).unwrap(),
                    fast.counts,
                    "'{spec}' {df} analytic"
                );
            }
        }
    });
}

// ---- batched estimation: count once, price many ----------------------

/// A random *valid* composed stack for one edge: optional value gate
/// (always first — the spec grammar rejects coding-before-gating),
/// optional BIC variant, optional DDCG group size. May be empty.
fn random_edge_spec(rng: &mut Rng64) -> String {
    let mut codecs: Vec<String> = Vec::new();
    if rng.chance(0.5) {
        codecs.push("zvcg".into());
    }
    if rng.chance(0.5) {
        let mode = ["bic-mantissa", "bic-full", "bic-segmented", "bic-exponent"]
            [rng.below(4)];
        let policy = if rng.chance(0.3) { "-mt" } else { "" };
        codecs.push(format!("{mode}{policy}"));
    }
    if rng.chance(0.4) {
        codecs.push(format!("ddcg16-g{}", [1usize, 2, 4, 8, 16][rng.below(5)]));
    }
    codecs.join("+")
}

/// A random valid full coding stack (possibly `baseline`).
fn random_stack(rng: &mut Rng64) -> sa_lowpower::coding::CodingStack {
    let w = random_edge_spec(rng);
    let i = random_edge_spec(rng);
    let mut clauses = Vec::new();
    if !w.is_empty() {
        clauses.push(format!("w:{w}"));
    }
    if !i.is_empty() {
        clauses.push(format!("i:{i}"));
    }
    let spec = if clauses.is_empty() { "baseline".to_string() } else { clauses.join(",") };
    sa_lowpower::coding::CodingStack::parse(&spec)
        .unwrap_or_else(|e| panic!("generated spec '{spec}': {e}"))
}

/// The batched-backend contract (see `engine/backend.rs`): for every
/// registry stack, `estimate_many` element `i` is bit-identical to the
/// standalone `estimate` of `stacks[i]` — and both equal the literal
/// per-cycle reference, so the shared `TileActivity` pass cannot drift
/// from the golden semantics.
#[test]
fn estimate_many_is_bit_exact_vs_sequential_and_reference() {
    check("estimate_many == N × estimate == reference", 8, |rng| {
        let (m, k, n) = (1 + rng.below(7), 1 + rng.below(18), 1 + rng.below(7));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let named = ConfigSet::all();
        let stacks: Vec<_> = named.iter().map(|(_, s)| s.clone()).collect();
        for df in [WS, OS] {
            let backends: [&dyn EstimatorBackend; 2] =
                [&AnalyticBackend, &CycleBackend];
            for backend in backends {
                let batched = backend.estimate_many(&t, &stacks, df).unwrap();
                assert_eq!(batched.len(), stacks.len());
                for (i, (name, stack)) in named.iter().enumerate() {
                    let single = backend.estimate(&t, stack, df).unwrap();
                    assert_eq!(
                        batched[i],
                        single,
                        "'{name}' {df} ({} backend)",
                        backend.name()
                    );
                    let golden = simulate_tile_reference(&t, stack, df);
                    assert_eq!(
                        batched[i],
                        golden.counts,
                        "'{name}' {df} ({} backend) vs literal reference",
                        backend.name()
                    );
                }
            }
        }
    });
}

/// Property clause over *arbitrary* composed stacks: one shared pass
/// priced under a random stack list equals per-stack estimation and the
/// literal reference, for random tiles × both dataflows × both
/// backends. Duplicate stacks in the list are legal and must reproduce
/// identical rows.
#[test]
fn estimate_many_matches_on_random_composed_stacks() {
    check("batched contract on random stacks", 8, |rng| {
        let (m, k, n) = (1 + rng.below(6), 1 + rng.below(16), 1 + rng.below(6));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let mut stacks: Vec<_> = (0..5).map(|_| random_stack(rng)).collect();
        // duplicates share cached IR state; both rows must still match
        stacks.push(stacks[0].clone());
        for df in [WS, OS] {
            let backends: [&dyn EstimatorBackend; 2] =
                [&AnalyticBackend, &CycleBackend];
            for backend in backends {
                let batched = backend.estimate_many(&t, &stacks, df).unwrap();
                for (i, stack) in stacks.iter().enumerate() {
                    assert_eq!(
                        batched[i],
                        backend.estimate(&t, stack, df).unwrap(),
                        "stack '{}' {df} ({} backend)",
                        stack.spec(),
                        backend.name()
                    );
                    assert_eq!(
                        batched[i],
                        simulate_tile_reference(&t, stack, df).counts,
                        "stack '{}' {df} vs literal reference",
                        stack.spec()
                    );
                }
            }
        }
    });
}

#[test]
fn estimate_many_holds_on_degenerate_tiles() {
    let mut rng = Rng64::new(0xFADE);
    let stacks: Vec<_> = ConfigSet::all().iter().map(|(_, s)| s.clone()).collect();
    for t in degenerate_tiles(&mut rng) {
        for df in [WS, OS] {
            let backends: [&dyn EstimatorBackend; 2] =
                [&AnalyticBackend, &CycleBackend];
            for backend in backends {
                let batched = backend.estimate_many(&t, &stacks, df).unwrap();
                for (i, stack) in stacks.iter().enumerate() {
                    assert_eq!(
                        batched[i],
                        backend.estimate(&t, stack, df).unwrap(),
                        "{df} {}x{}x{} ({} backend)",
                        t.m,
                        t.k,
                        t.n,
                        backend.name()
                    );
                }
            }
        }
    }
}

// ---- specialized kernels: fused pricing == generic interpreter -------

use sa_lowpower::bf16::Bf16;
use sa_lowpower::coding::{
    specialize, specializes, AreaFootprint, CodecRole, CodedWord, CodingStack,
    EdgeStack, LaneCoder, StreamCodec, KERNEL_SHAPES,
};
use sa_lowpower::engine::{InterpreterAnalyticBackend, InterpreterCycleBackend};
use std::sync::Arc;

/// One full-stack spec per specialized kernel shape, keyed by the
/// [`KERNEL_SHAPES`] name both of its edges compile to. Every shape the
/// compiler ships must be named here — `sa-lint`'s `kernel-registration`
/// rule checks that each `KERNEL_SHAPES` string appears in this file, so
/// a new kernel cannot land without a conformance stack exercising it.
const SHAPE_STACKS: [(&str, &str); 8] = [
    ("plain", "baseline"),
    ("zvcg", "w:zvcg,i:zvcg"),
    ("bic", "w:bic-mantissa,i:bic-full-mt"),
    ("zvcg+bic", "w:zvcg+bic-segmented,i:zvcg+bic-exponent-mt"),
    ("ddcg", "w:ddcg16-g4,i:ddcg16-g1"),
    ("zvcg+ddcg", "w:zvcg+ddcg16-g8,i:zvcg+ddcg16-g16"),
    ("bic+ddcg", "w:bic-full+ddcg16-g2,i:bic-mantissa-mt+ddcg16-g4"),
    ("zvcg+bic+ddcg", "w:zvcg+bic-exponent+ddcg16-g8,i:zvcg+bic-mantissa+ddcg16-g2"),
];

#[test]
fn every_kernel_shape_is_named_and_specializes() {
    let mut seen: Vec<&str> = Vec::new();
    for (shape, spec) in SHAPE_STACKS {
        assert!(KERNEL_SHAPES.contains(&shape), "'{shape}' is not a kernel shape");
        let stack = CodingStack::parse(spec).unwrap();
        assert!(specializes(&stack), "'{spec}' must compile");
        let kernels = specialize(&stack).unwrap();
        assert_eq!(kernels.west.shape_name(), shape, "'{spec}' west edge");
        assert_eq!(kernels.north.shape_name(), shape, "'{spec}' north edge");
        seen.push(shape);
    }
    for shape in KERNEL_SHAPES {
        assert!(seen.contains(&shape), "shape '{shape}' has no conformance stack");
    }
}

/// The tentpole contract: for every kernel shape and every registry
/// stack, the fused specialized pricing equals the generic `StreamCodec`
/// interpreter — full ledgers, both dataflows, both backend families —
/// and both equal the literal per-cycle reference.
#[test]
fn specialized_pricing_matches_the_interpreter_on_every_shape() {
    check("fused kernels == StreamCodec interpreter", 10, |rng| {
        let (m, k, n) = (1 + rng.below(6), 1 + rng.below(18), 1 + rng.below(6));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let mut stacks: Vec<(String, CodingStack)> = SHAPE_STACKS
            .iter()
            .map(|(_, spec)| (spec.to_string(), CodingStack::parse(spec).unwrap()))
            .collect();
        for (name, stack) in ConfigSet::all().iter() {
            stacks.push((name.clone(), stack.clone()));
        }
        for (name, stack) in &stacks {
            for df in [WS, OS] {
                let fused = AnalyticBackend.estimate(&t, stack, df).unwrap();
                let interp =
                    InterpreterAnalyticBackend.estimate(&t, stack, df).unwrap();
                assert_eq!(fused, interp, "'{name}' {df} analytic");
                assert_eq!(
                    CycleBackend.estimate_many(&t, &[stack.clone()], df).unwrap(),
                    InterpreterCycleBackend
                        .estimate_many(&t, &[stack.clone()], df)
                        .unwrap(),
                    "'{name}' {df} cycle (batched)"
                );
                assert_eq!(
                    fused,
                    simulate_tile_reference(&t, stack, df).counts,
                    "'{name}' {df} vs literal reference"
                );
            }
        }
    });
}

/// Same differential over *random composed* stacks (any gate/BIC/DDCG
/// combination the spec grammar admits on either edge) — the fused path
/// must match the interpreter on stacks nobody hand-picked.
#[test]
fn specialized_pricing_matches_the_interpreter_on_random_stacks() {
    check("fused == interpreter on random composed stacks", 12, |rng| {
        let (m, k, n) = (1 + rng.below(6), 1 + rng.below(16), 1 + rng.below(6));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        let stacks: Vec<CodingStack> = (0..4).map(|_| random_stack(rng)).collect();
        // every grammar-built stack is made of in-tree codecs only
        for stack in &stacks {
            assert!(specializes(stack), "'{}' must compile", stack.spec());
        }
        for df in [WS, OS] {
            assert_eq!(
                AnalyticBackend.estimate_many(&t, &stacks, df).unwrap(),
                InterpreterAnalyticBackend.estimate_many(&t, &stacks, df).unwrap(),
                "{df} analytic batched"
            );
            assert_eq!(
                CycleBackend.estimate_many(&t, &stacks, df).unwrap(),
                InterpreterCycleBackend.estimate_many(&t, &stacks, df).unwrap(),
                "{df} cycle batched"
            );
            for stack in &stacks {
                assert_eq!(
                    AnalyticBackend.estimate(&t, stack, df).unwrap(),
                    InterpreterAnalyticBackend.estimate(&t, stack, df).unwrap(),
                    "'{}' {df}",
                    stack.spec()
                );
            }
        }
    });
}

#[test]
fn specialized_pricing_holds_on_degenerate_tiles() {
    let mut rng = Rng64::new(0xF00D);
    let stacks: Vec<CodingStack> = SHAPE_STACKS
        .iter()
        .map(|(_, spec)| CodingStack::parse(spec).unwrap())
        .collect();
    for t in degenerate_tiles(&mut rng) {
        for df in [WS, OS] {
            assert_eq!(
                AnalyticBackend.estimate_many(&t, &stacks, df).unwrap(),
                InterpreterAnalyticBackend.estimate_many(&t, &stacks, df).unwrap(),
                "{df} {}x{}x{}",
                t.m,
                t.k,
                t.n
            );
            assert_eq!(
                CycleBackend.estimate_many(&t, &stacks, df).unwrap(),
                InterpreterCycleBackend.estimate_many(&t, &stacks, df).unwrap(),
                "{df} {}x{}x{} cycle",
                t.m,
                t.k,
                t.n
            );
        }
    }
}

/// An out-of-tree transform the specializer has never heard of: XORs a
/// fixed mask onto the low byte (self-inverse, so `decode∘encode` is the
/// identity). Exists to prove the fallback path, not to save power.
#[derive(Debug)]
struct XorScramble;

const SCRAMBLE_MASK: u16 = 0x00A5;

struct XorScrambleLane;

impl LaneCoder for XorScrambleLane {
    fn encode(&mut self, word: Bf16) -> CodedWord {
        CodedWord::Tx { word: Bf16::from_bits(word.0 ^ SCRAMBLE_MASK), sideband: 0 }
    }
}

impl StreamCodec for XorScramble {
    fn name(&self) -> String {
        "xor-scramble".into()
    }

    fn role(&self) -> CodecRole {
        CodecRole::Transform
    }

    fn cover_mask(&self) -> u16 {
        SCRAMBLE_MASK
    }

    fn begin(&self) -> Box<dyn LaneCoder> {
        Box::new(XorScrambleLane)
    }

    fn decode(&self, word: Bf16, _sideband: u8) -> Bf16 {
        Bf16::from_bits(word.0 ^ SCRAMBLE_MASK)
    }

    fn area(&self) -> AreaFootprint {
        AreaFootprint::default()
    }
}

/// A specialize miss must be silent: an unknown codec makes the stack
/// uncompilable, `specialize` returns `None`, and the default backends
/// transparently price through the generic interpreter — matching the
/// interpreter-forced variants and the literal reference exactly.
#[test]
fn unknown_codecs_fall_back_to_the_generic_interpreter() {
    let west =
        EdgeStack::from_codecs(vec![Arc::new(XorScramble) as Arc<dyn StreamCodec>])
            .unwrap();
    let stack = CodingStack { west, north: EdgeStack::empty() };
    assert!(!specializes(&stack), "out-of-tree codec must not compile");
    assert!(specialize(&stack).is_none());

    let mut rng = Rng64::new(0xABAD);
    let t = random_tile(&mut rng, 4, 12, 4, 0.4, 0.2);
    for df in [WS, OS] {
        let fused = AnalyticBackend.estimate(&t, &stack, df).unwrap();
        let interp = InterpreterAnalyticBackend.estimate(&t, &stack, df).unwrap();
        assert_eq!(fused, interp, "{df}: fallback must be bit-identical");
        assert_eq!(
            fused,
            simulate_tile_reference(&t, &stack, df).counts,
            "{df}: fallback vs literal reference"
        );
        // and the f32 outputs survive the scramble (decode∘encode = id)
        assert_eq!(simulate_tile(&t, &stack, df).c, t.reference_result(), "{df}");
    }
}

// ---- boundary: zero-K tiles are rejected at construction -------------

#[test]
#[should_panic(expected = "empty tile")]
fn zero_k_tiles_are_rejected() {
    // K = 0 has no stream slots; the Tile constructor is the contract
    // boundary and must refuse it for every downstream engine at once.
    let _ = Tile::from_f32(&[], &[], 2, 0, 3);
}

#[test]
#[should_panic(expected = "empty tile")]
fn zero_m_tiles_are_rejected() {
    let _ = Tile::from_f32(&[], &[1.0, 2.0], 0, 1, 2);
}

// ---- engine-level: the full sweep matrix stays bit-exact -------------

#[test]
fn transformer_sweeps_agree_across_backends_and_dataflows() {
    // Acceptance criterion: the transformer workload runs through
    // `SaEngine::sweep` on both backends and both dataflows, and the two
    // backends produce bit-identical ledgers cell by cell.
    let net = Network::by_name("transformer").unwrap();
    for df in [WS, OS] {
        let sweep_of = |kind: BackendKind| {
            SaEngine::builder()
                .max_tiles_per_layer(1)
                .backend(kind)
                .dataflow(df)
                .threads(2)
                .build()
                .unwrap()
                .sweep(&net)
                .unwrap()
        };
        let a = sweep_of(BackendKind::Analytic);
        let c = sweep_of(BackendKind::Cycle);
        assert_eq!(a.dataflow, df.name());
        assert_eq!(c.dataflow, df.name());
        assert_eq!(a.layers.len(), net.layers.len());
        for (la, lc) in a.layers.iter().zip(&c.layers) {
            for (ra, rc) in la.results.iter().zip(&lc.results) {
                assert_eq!(
                    ra.counts, rc.counts,
                    "layer {} cfg {} {df}",
                    la.layer_name, ra.config_name
                );
                assert_eq!(ra.energy, rc.energy, "layer {} {df}", la.layer_name);
            }
        }
        assert!(a.total_energy("baseline") > 0.0);
    }
}

// ---- cache clause: hits are byte-identical to recomputation ----------

/// The result-cache contract (see `engine/cache.rs`): a sweep served
/// from the cache renders the same report, byte for byte, as the same
/// sweep recomputed — across the full backend × dataflow matrix. Only
/// the provenance stats may differ, so the clause nulls them out before
/// comparing and asserts on them separately.
#[test]
fn cached_sweeps_are_byte_identical_to_cache_off() {
    use sa_lowpower::engine::CachePolicy;
    let net = Network::by_name("transformer").unwrap();
    for kind in [BackendKind::Analytic, BackendKind::Cycle] {
        for df in [WS, OS] {
            let engine_with = |cache: CachePolicy| {
                SaEngine::builder()
                    .max_tiles_per_layer(1)
                    .backend(kind)
                    .dataflow(df)
                    .threads(2)
                    .cache(cache)
                    .build()
                    .unwrap()
            };
            let off = engine_with(CachePolicy::Off).sweep(&net).unwrap();
            assert!(off.cache.is_none(), "cache-off sweeps carry no stats");

            // One cached engine, swept cold then warm.
            let cached = engine_with(CachePolicy::Memory { budget: 16 << 20 });
            let mut cold = cached.sweep(&net).unwrap();
            let mut warm = cached.sweep(&net).unwrap();

            let cold_stats = cold.cache.take().unwrap();
            let warm_stats = warm.cache.take().unwrap();
            assert!(cold_stats.misses > 0, "{kind:?} {df}: cold run must miss");
            // Stats are cumulative over the engine's store: the warm
            // sweep adds hits but not a single new miss or insertion.
            assert!(
                warm_stats.hits > cold_stats.hits,
                "{kind:?} {df}: warm run must hit (warm {warm_stats:?} vs \
                 cold {cold_stats:?})"
            );
            assert_eq!(
                warm_stats.misses, cold_stats.misses,
                "{kind:?} {df}: warm run must add no misses"
            );
            assert_eq!(
                warm_stats.insertions, cold_stats.insertions,
                "{kind:?} {df}: warm run must insert nothing"
            );

            // With provenance nulled, all three runs are byte-identical:
            // a cache hit is indistinguishable from recomputation.
            assert_eq!(off.to_json(), cold.to_json(), "{kind:?} {df} cold");
            assert_eq!(off.to_json(), warm.to_json(), "{kind:?} {df} warm");
        }
    }
}

// ---- robustness clause: failures never perturb concurrent results ----

/// A failed (here: panicked) job sharing the pool with a sweep must not
/// change one byte of that sweep's JSON relative to a fresh, fault-free
/// pool — failure isolation is part of the determinism contract, not
/// just an engine feature.
#[test]
fn faulted_job_never_perturbs_concurrent_sweep_json() {
    use sa_lowpower::workload::Layer;
    let net = Network::by_name("transformer").unwrap();
    let engine_with = |fault: FaultPlan| {
        SaEngine::builder()
            .max_tiles_per_layer(2)
            .configs(ConfigSet::paper())
            .threads(3)
            .fault_plan(fault)
            .build()
            .unwrap()
    };

    // Fault targets only the layer named "doomed" — absent from the net,
    // so the sweep itself never matches a site.
    let armed = engine_with(FaultPlan::parse("panic@doomed:0").unwrap());
    let doomed = armed
        .submit(LayerJob::synthetic(Layer::gemm_layer("doomed", 6, 8, 6, false), 99))
        .unwrap();
    let sweep = armed.sweep(&net).unwrap();
    match doomed.wait() {
        Err(EngineError::WorkerPanic { .. }) => {}
        other => panic!("doomed job must fail with WorkerPanic, got {other:?}"),
    }

    let clean = engine_with(FaultPlan::none()).sweep(&net).unwrap();
    assert_eq!(
        sweep.to_json(),
        clean.to_json(),
        "sweep JSON must be byte-identical despite the concurrent fault"
    );
    // And the recovered pool still serves byte-identical work afterwards.
    let again = armed.sweep(&net).unwrap();
    assert_eq!(again.to_json(), clean.to_json());
}

// ---- rejection: malformed specs and documents fail typed, not loud ---

#[test]
fn malformed_fault_specs_and_jobs_are_rejected_with_typed_errors() {
    use sa_lowpower::workload::Layer;
    // Fault-plan grammar errors are InvalidSpec.
    for bad in ["panic@x", "explode@*:0", "delay@*:0", "panic@*:zero"] {
        match FaultPlan::parse(bad) {
            Err(EngineError::InvalidSpec(_)) => {}
            other => panic!("'{bad}' must be InvalidSpec, got {other:?}"),
        }
    }
    // Workload errors are InvalidWorkload, raised at the submit boundary
    // (never inside a worker).
    let engine = SaEngine::builder()
        .max_tiles_per_layer(1)
        .threads(1)
        .build()
        .unwrap();
    let l = Layer::gemm_layer("g", 4, 4, 4, false);
    match engine.submit(LayerJob::with_data(l, 0, vec![0.0; 3], vec![0.0; 16])) {
        Err(EngineError::InvalidWorkload(_)) => {}
        other => panic!("short feature map must be InvalidWorkload, got {other:?}"),
    }
}

#[test]
fn malformed_sweep_documents_are_rejected() {
    // Truncated / non-JSON / wrong-schema documents all fail cleanly.
    assert!(SweepDoc::parse("{\"schema\": \"sa-lowpower.sweep-report.v3\"").is_err());
    assert!(SweepDoc::parse("not json at all").is_err());
    assert!(SweepDoc::parse("{\"schema\": \"someone-elses.report.v9\"}").is_err());
}

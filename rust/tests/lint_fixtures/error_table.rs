// Fixture: rule `error-table-sync`. Lexed under the synthetic path
// `rust/src/engine/error.rs` by lint_rules.rs; never compiled. The
// harness pairs it with a synthetic README whose table carries a wrong
// exit code for `Internal`. Expected findings: line 9 (`Timeout` has
// no kind() arm) plus the README row mismatch.

pub enum EngineError {
    InvalidSpec(String),
    Timeout,
    Internal(String),
}

impl EngineError {
    pub fn kind(&self) -> &'static str {
        match self {
            EngineError::InvalidSpec(_) => "invalid-spec",
            EngineError::Internal(_) => "internal",
            _ => "unknown",
        }
    }

    pub fn exit_code(&self) -> i32 {
        match self {
            EngineError::InvalidSpec(_) => 2,
            EngineError::Timeout => 7,
            EngineError::Internal(_) => 10,
        }
    }
}

// Fixture: rule `schema-tags`. Lexed under a synthetic `rust/src/`
// path by lint_rules.rs alongside a synthetic golden that pins
// "sa-lowpower.fixture-pinned.v1" plus an orphan
// "sa-lowpower.fixture-orphan.v3" that no source file emits.
// Expected findings: line 8 (ghost tag with no golden/script sink)
// and one sink-side finding for the orphan tag. Line 10 is clean.

pub const GHOST_SCHEMA: &str = "sa-lowpower.fixture-ghost.v2";

pub const PINNED_SCHEMA: &str = "sa-lowpower.fixture-pinned.v1";

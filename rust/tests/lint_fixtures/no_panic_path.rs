// Fixture: rule `no-panic-path`. The drill harness (lint_rules.rs)
// lexes this under a synthetic `rust/src/engine/` path; it is never
// compiled. Expected findings: lines 7, 11, 15, 19. The pragma'd site
// (line 24) and everything under #[cfg(test)] must stay silent.

pub fn risky(v: Option<u32>) -> u32 {
    v.unwrap()
}

pub fn message(v: Result<u32, String>) -> u32 {
    v.expect("must hold")
}

pub fn explode() {
    panic!("boom");
}

pub fn never() {
    unreachable!();
}

pub fn allowed(v: Option<u32>) -> u32 {
    // sa-lint: allow(no-panic-path) reason="fixture proves pragma suppression"
    v.unwrap()
}

pub fn fine(v: Option<u32>) -> u32 {
    v.unwrap_or(0)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        assert_eq!(Some(1).unwrap(), 1);
    }
}

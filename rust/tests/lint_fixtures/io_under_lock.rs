// Fixture: rule `io-under-lock`. Lexed under a synthetic
// `rust/src/engine/` path by lint_rules.rs; never compiled.
// Expected findings: line 10 (file open while the `g` guard is held)
// and line 11 (drop of a non-guard while the guard is held). After
// `drop(g)` the same operations (lines 13-14) must stay silent, as
// must the pragma'd write (line 20).

pub fn flush_under_lock(m: &std::sync::Mutex<u32>, engine: Vec<u8>) {
    let g = lock_recover(m);
    File::create("state.bin");
    drop(engine);
    drop(g);
    File::create("state2.bin");
    drop(m);
}

pub fn audited_flush(m: &std::sync::Mutex<u32>) {
    let g = lock_recover(m);
    // sa-lint: allow(io-under-lock) reason="fixture proves pragma suppression"
    File::create("state.bin");
    drop(g);
}

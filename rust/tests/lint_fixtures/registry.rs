// Fixture: rule `registry-hygiene`. Lexed under the synthetic path
// `rust/src/engine/registry.rs` by lint_rules.rs; never compiled.
// Expected findings: line 16 (alias `base` duplicates line 15's) and
// line 17 (spec `w:frobnicate` is outside the --coding grammar).
// The `name:` fn parameter in `by_name` (line 20) must NOT read as a
// table row — the walker is bounded to the initializer.

pub struct ConfigRow {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub spec: &'static str,
}

pub const CONFIG_TABLE: &[ConfigRow] = &[
    ConfigRow { name: "baseline", aliases: &["base"], spec: "baseline" },
    ConfigRow { name: "bic", aliases: &["base"], spec: "w:bic-mantissa" },
    ConfigRow { name: "broken", aliases: &[], spec: "w:frobnicate" },
];

pub fn by_name(name: &str) -> Option<&'static ConfigRow> {
    CONFIG_TABLE.iter().find(|r| r.name == name)
}

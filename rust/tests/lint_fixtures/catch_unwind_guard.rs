// Fixture: rule `catch-unwind-guard`. Lexed under a synthetic
// `rust/src/engine/` path by lint_rules.rs; never compiled.
// Expected finding: line 11 (catch_unwind with no guard machinery in
// the enclosing fn body). The import line (8) is ignored, the guarded
// fn (line 14) is clean because `ItemGuard` appears in its body, and
// the pragma'd call (line 21) is suppressed.

use std::panic::{catch_unwind, AssertUnwindSafe};

pub fn bare(job: impl FnOnce() + std::panic::UnwindSafe) {
    let _ = catch_unwind(job);
}

pub fn guarded(job: impl FnOnce() + std::panic::UnwindSafe) {
    let _guard = ItemGuard;
    let _ = catch_unwind(job);
}

pub fn audited(job: impl FnOnce() + std::panic::UnwindSafe) {
    // sa-lint: allow(catch-unwind-guard) reason="fixture proves pragma suppression"
    let _ = catch_unwind(job);
}

// Fixture: rule `raw-lock`. Lexed under a synthetic `rust/src/engine/`
// path by lint_rules.rs; never compiled. Expected finding: line 7.
// The body of a fn literally named `lock_recover` (line 13) and the
// pragma'd call (line 18) must stay silent.

pub fn checkout(m: &std::sync::Mutex<u32>) -> u32 {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    *g
}

pub fn lock_recover(m: &std::sync::Mutex<u32>) -> u32 {
    // Exempt: this IS the recovery shim the rule points callers at.
    *m.lock().unwrap_or_else(|e| e.into_inner())
}

pub fn audited(m: &std::sync::Mutex<u32>) -> u32 {
    // sa-lint: allow(raw-lock) reason="fixture proves pragma suppression"
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    *g
}

// Fixture: rule `test-registration`. Registered by lint_rules.rs as a
// synthetic top-level integration test file; never compiled. It
// contains no #[test], so the rule fires at line 1. A well-formed
// pragma on line 1 of a variant copy suppresses it.

pub fn helper_only() -> u32 {
    42
}

//! Property-test battery over the whole modelling stack.
//!
//! The central invariant of the reproduction: the analytic activity model
//! and the cycle-accurate simulator agree on **exact integer counts** for
//! every coding configuration, tile geometry and sparsity pattern. Plus
//! the coding-theory guarantees (BIC bounds, ZVCG transparency) at scale.

use sa_lowpower::activity::{
    broadcast_mask, ham16, ham16_masked, ham16_packed, ham16_packed_masked,
    ham16_slice, ham16_slice_masked, pack4, stream_toggles, ActivityCounts,
};
use sa_lowpower::bf16::Bf16;
use sa_lowpower::coding::{
    decode, BicEncoder, BicMode, BicPolicy, CodingStack, SaCodingConfig,
};
use sa_lowpower::engine::{AnalyticBackend, CycleBackend, EstimatorBackend};
use sa_lowpower::power::EnergyModel;
use sa_lowpower::sa::{
    analyze_tile, simulate_tile, simulate_tile_reference, Dataflow, Tile,
};
use sa_lowpower::util::prop::check;
use sa_lowpower::util::Rng64;

const WS: Dataflow = Dataflow::WeightStationary;
const OS: Dataflow = Dataflow::OutputStationary;

fn random_tile(
    rng: &mut Rng64,
    m: usize,
    k: usize,
    n: usize,
    pz_a: f64,
    pz_b: f64,
) -> Tile {
    let a: Vec<f32> = (0..m * k)
        .map(|_| if rng.chance(pz_a) { 0.0 } else { rng.normal() as f32 })
        .collect();
    let b: Vec<f32> = (0..k * n)
        .map(|_| if rng.chance(pz_b) { 0.0 } else { (rng.normal() * 0.1) as f32 })
        .collect();
    Tile::from_f32(&a, &b, m, k, n)
}

fn stack(spec: &str) -> CodingStack {
    CodingStack::parse(spec).unwrap_or_else(|e| panic!("{spec}: {e}"))
}

fn all_configs() -> Vec<CodingStack> {
    let mut v: Vec<CodingStack> = [
        "baseline",
        "proposed",
        "bic-only",
        "zvcg-only",
        "bic-full",
        "bic-segmented",
        "bic-exponent",
    ]
    .iter()
    .map(|n| SaCodingConfig::by_name(n).unwrap().stack())
    .collect();
    // legacy ablation extras: weight gating, input BIC, min-transitions
    v.push(
        SaCodingConfig { weight_zvcg: true, ..SaCodingConfig::proposed() }.stack(),
    );
    v.push(
        SaCodingConfig {
            input_bic: BicMode::MantissaOnly,
            ..SaCodingConfig::baseline()
        }
        .stack(),
    );
    v.push(
        SaCodingConfig {
            bic_policy: BicPolicy::MinTransitions,
            ..SaCodingConfig::proposed()
        }
        .stack(),
    );
    // composed spec-grammar stacks the closed struct never expressed
    v.push(stack("w:ddcg16-g4,i:ddcg16-g4"));
    v.push(stack("w:zvcg+bic-full+ddcg16-g8,i:zvcg+ddcg16-g2"));
    v.push(stack("i:zvcg+bic-segmented-mt"));
    v
}

#[test]
fn analytic_equals_cycle_sim_everywhere() {
    check("analytic == cycle-sim, full config matrix", 30, |rng| {
        let (m, k, n) = (1 + rng.below(16), 1 + rng.below(40), 1 + rng.below(16));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for cfg in all_configs() {
            for df in [WS, OS] {
                let golden = simulate_tile(&t, &cfg, df).counts;
                let fast = analyze_tile(&t, &cfg, df);
                assert_eq!(fast, golden, "cfg {cfg:?} {df} tile {m}x{k}x{n}");
            }
        }
    });
}

#[test]
fn analytic_equals_cycle_sim_paper_geometry() {
    // The paper's exact geometry: 16×16 PEs, long K streams.
    check("analytic == cycle-sim at 16x16, long K", 5, |rng| {
        let t = random_tile(rng, 16, 256, 16, 0.5, 0.05);
        for cfg in [CodingStack::baseline(), SaCodingConfig::proposed().stack()] {
            for df in [WS, OS] {
                assert_eq!(
                    analyze_tile(&t, &cfg, df),
                    simulate_tile(&t, &cfg, df).counts
                );
            }
        }
    });
}

#[test]
fn backends_agree_bit_exactly() {
    // The engine's backend contract: AnalyticBackend and CycleBackend
    // must agree on the streaming toggle counts for a shared tile — and,
    // since both implement the same RTL semantics, on the whole ledger,
    // under either dataflow.
    check("backend trait: analytic == cycle on shared tiles", 25, |rng| {
        let (m, k, n) = (1 + rng.below(14), 1 + rng.below(48), 1 + rng.below(14));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for cfg in all_configs() {
            for df in [WS, OS] {
                let a = AnalyticBackend.estimate(&t, &cfg, df).unwrap();
                let c = CycleBackend.estimate(&t, &cfg, df).unwrap();
                assert_eq!(
                    a.streaming_toggles(),
                    c.streaming_toggles(),
                    "streaming toggles diverge: cfg {cfg:?} {df} tile {m}x{k}x{n}"
                );
                assert_eq!(
                    a, c,
                    "full ledger diverges: cfg {cfg:?} {df} tile {m}x{k}x{n}"
                );
            }
        }
    });
}

#[test]
fn functional_transparency_of_all_configs() {
    check("C = A×B under every coding config and dataflow", 20, |rng| {
        let t = random_tile(rng, 8, 24, 8, 0.4, 0.1);
        let want = t.reference_result();
        for cfg in all_configs() {
            for df in [WS, OS] {
                let r = simulate_tile(&t, &cfg, df);
                assert_eq!(r.c, want, "cfg {cfg:?} {df}");
            }
        }
    });
}

#[test]
fn mac_slot_conservation() {
    check("active + gated + zero-product == M·N·K", 30, |rng| {
        let (m, k, n) = (1 + rng.below(10), 1 + rng.below(30), 1 + rng.below(10));
        let t = random_tile(rng, m, k, n, 0.6, 0.3);
        for cfg in all_configs() {
            for df in [WS, OS] {
                let c = analyze_tile(&t, &cfg, df);
                assert_eq!(c.total_mac_slots(), t.mac_slots(), "cfg {cfg:?} {df}");
            }
        }
    });
}

#[test]
fn proposed_never_increases_streaming_toggles() {
    // BIC (classic, per segment) can only reduce or keep data-line
    // transitions; ZVCG can only remove them. Sidebands are accounted
    // separately by the energy model, but the *data* pipelines must never
    // get worse — under either dataflow.
    check("proposed data toggles <= baseline", 30, |rng| {
        let pz = rng.uniform();
        let t = random_tile(rng, 12, 48, 12, pz, 0.1);
        for df in [WS, OS] {
            let base = analyze_tile(&t, &CodingStack::baseline(), df);
            let prop = analyze_tile(&t, &SaCodingConfig::proposed().stack(), df);
            assert!(prop.west_data_toggles <= base.west_data_toggles);
            assert!(prop.north_data_toggles <= base.north_data_toggles);
        }
    });
}

#[test]
fn bic_never_increases_hamming_on_any_stream() {
    // The per-dataflow coding invariant: every BIC mode may only lower
    // (or keep) the data-line Hamming activity of the stream it encodes,
    // on both the weight (North) and input (West) side.
    check("BIC Hamming bound per stream and dataflow", 20, |rng| {
        let t = random_tile(rng, 6, 40, 6, 0.3, 0.1);
        for df in [WS, OS] {
            let base = analyze_tile(&t, &CodingStack::baseline(), df);
            for name in ["bic-only", "bic-full", "bic-segmented", "bic-exponent"] {
                let c = analyze_tile(
                    &t,
                    &SaCodingConfig::by_name(name).unwrap().stack(),
                    df,
                );
                assert!(
                    c.north_data_toggles <= base.north_data_toggles,
                    "{name} {df}: north {} > {}",
                    c.north_data_toggles,
                    base.north_data_toggles
                );
            }
            let input_bic = SaCodingConfig {
                input_bic: sa_lowpower::coding::BicMode::MantissaOnly,
                ..SaCodingConfig::baseline()
            }
            .stack();
            let c = analyze_tile(&t, &input_bic, df);
            assert!(
                c.west_data_toggles <= base.west_data_toggles,
                "input-side BIC {df}: west {} > {}",
                c.west_data_toggles,
                base.west_data_toggles
            );
        }
    });
}

#[test]
fn zvcg_savings_monotone_in_sparsity() {
    // More zeros -> at least as many gated MACs.
    check("gating grows with sparsity", 10, |rng| {
        let seed = rng.next_u64();
        for df in [WS, OS] {
            let mut gated_prev = 0u64;
            for pz10 in [1usize, 3, 5, 7, 9] {
                let mut r2 = Rng64::new(seed);
                let t = random_tile(&mut r2, 8, 64, 8, pz10 as f64 / 10.0, 0.0);
                let c = analyze_tile(&t, &stack("i:zvcg"), df);
                assert!(
                    c.gated_macs >= gated_prev,
                    "{df} sparsity {pz10}/10: {} < {gated_prev}",
                    c.gated_macs
                );
                gated_prev = c.gated_macs;
            }
        }
    });
}

#[test]
fn zvcg_energy_monotone_in_operand_zero_fraction() {
    // On *nested* zero patterns (each step zeroes strictly more of the
    // same operand matrix), ZVCG total energy must be non-increasing.
    // Two ingredients: the Hamming triangle inequality guarantees the
    // shortened register/latch sequences cannot toggle more, and under
    // the *default* EnergyModel the removed register clocks + MAC work
    // strictly dominate the one overhead that can grow (up to 2 extra
    // is-zero sideband toggles per zeroed value: 16·e_ff_clk = 14.4 fJ
    // saved per register vs ≤ 2·(e_ff_toggle+e_wire_toggle) = 7 fJ
    // added). A future constant set that inverts that dominance would
    // legitimately fail this test — the paper's sizing assumption, not
    // the simulator, would be what changed.
    check("ZVCG energy non-increasing on nested zero sets", 10, |rng| {
        let (m, k, n) = (6, 48, 6);
        let model = EnergyModel::default();
        let a_dense: Vec<f32> =
            (0..m * k).map(|_| 0.2 + rng.normal().abs() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| (rng.normal() * 0.1) as f32).collect();
        // a random zeroing order over A's positions
        let mut order: Vec<usize> = (0..m * k).collect();
        for i in (1..order.len()).rev() {
            order.swap(i, rng.below(i + 1));
        }
        for df in [WS, OS] {
            let mut a = a_dense.clone();
            let mut prev_energy = f64::INFINITY;
            let mut prev_zf = -1.0f64;
            for step in 0..=8 {
                let cut = step * (m * k) / 8;
                for &p in &order[..cut] {
                    a[p] = 0.0;
                }
                let t = Tile::from_f32(&a, &b, m, k, n);
                let zf = t.input_zero_fraction();
                assert!((0.0..=1.0).contains(&zf), "zero frac {zf}");
                assert!(zf >= prev_zf, "nested sets: {zf} < {prev_zf}");
                prev_zf = zf;
                let counts = analyze_tile(&t, &stack("i:zvcg"), df);
                let e = model.energy(&counts).total();
                assert!(
                    e <= prev_energy,
                    "{df} step {step}: energy {e} > {prev_energy} (zf {zf})"
                );
                prev_energy = e;
            }
        }
    });
}

#[test]
fn bic_classic_bound_on_tile_streams() {
    // After mantissa BIC, no weight transfer flips more than 3 of the 7
    // mantissa lines (Stan–Burleson bound at w=7).
    check("BIC per-transfer bound on tiles", 20, |rng| {
        let t = random_tile(rng, 4, 32, 4, 0.0, 0.0);
        for j in 0..t.n {
            let col = t.b_col(j);
            let mut enc = BicEncoder::new(BicMode::MantissaOnly, BicPolicy::Classic);
            let (tx, _) = enc.encode_stream(col);
            let mut prev = 0u16;
            for &w in &tx {
                assert!(ham16(prev & 0x7F, w.0 & 0x7F) <= 3);
                prev = w.0;
            }
        }
    });
}

#[test]
fn bic_decode_recovers_on_tile_streams() {
    check("encode->decode identity on tile streams", 20, |rng| {
        let t = random_tile(rng, 4, 40, 4, 0.0, 0.0);
        for mode in [
            BicMode::MantissaOnly,
            BicMode::FullBus,
            BicMode::Segmented,
            BicMode::ExponentOnly,
        ] {
            for j in 0..t.n {
                let col = t.b_col(j);
                let mut enc = BicEncoder::new(mode, BicPolicy::Classic);
                let (tx, inv) = enc.encode_stream(col);
                for i in 0..col.len() {
                    let d = decode(
                        mode,
                        sa_lowpower::coding::Encoded { tx: tx[i], inv: inv[i] },
                    );
                    assert_eq!(d.0, col[i].0);
                }
            }
        }
    });
}

#[test]
fn counts_additive_ledger_algebra() {
    check("ledger addition is component-wise", 20, |rng| {
        let t1 = random_tile(rng, 4, 16, 4, 0.3, 0.1);
        let t2 = random_tile(rng, 4, 16, 4, 0.5, 0.2);
        let c1 = analyze_tile(&t1, &SaCodingConfig::proposed().stack(), WS);
        let c2 = analyze_tile(&t2, &SaCodingConfig::proposed().stack(), WS);
        let mut sum = ActivityCounts::default();
        sum.add(&c1);
        sum.add(&c2);
        assert_eq!(
            sum.west_data_toggles,
            c1.west_data_toggles + c2.west_data_toggles
        );
        assert_eq!(sum.cycles, c1.cycles + c2.cycles);
        assert_eq!(
            sum.streaming_toggles(),
            c1.streaming_toggles() + c2.streaming_toggles()
        );
    });
}

#[test]
fn stream_toggle_counting_matches_naive() {
    check("stream_toggles == naive pairwise hamming", 100, |rng| {
        let n = rng.below(100);
        let s: Vec<Bf16> = (0..n)
            .map(|_| Bf16::from_bits(rng.next_u32() as u16))
            .collect();
        let mut want = 0u64;
        let mut prev = 0u16;
        for v in &s {
            want += (prev ^ v.0).count_ones() as u64;
            prev = v.0;
        }
        assert_eq!(stream_toggles(Bf16::ZERO, &s), want);
    });
}

#[test]
fn packed_hamming_is_bit_identical_to_scalar() {
    // The word-packing contract: every packed/slice/masked variant is an
    // exact reformulation of Σ ham16, for all lengths, alignment phases
    // and masks.
    check("ham16_packed == Σ ham16 (all forms)", 200, |rng| {
        let n = rng.below(130);
        let mask = rng.next_u32() as u16;
        let a: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();
        let b: Vec<u16> = (0..n).map(|_| rng.next_u32() as u16).collect();

        let scalar: u64 = a.iter().zip(&b).map(|(&x, &y)| ham16(x, y) as u64).sum();
        assert_eq!(ham16_slice(&a, &b), scalar);

        let scalar_m: u64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| ham16_masked(x, y, mask) as u64)
            .sum();
        assert_eq!(ham16_slice_masked(&a, &b, mask), scalar_m);

        // offset subslices exercise every unaligned load phase
        if n >= 8 {
            let off = 1 + rng.below(3);
            let want: u64 = a[off..]
                .iter()
                .zip(&b[off..])
                .map(|(&x, &y)| ham16(x, y) as u64)
                .sum();
            assert_eq!(ham16_slice(&a[off..], &b[off..]), want, "offset {off}");
        }

        // 4-lane packed words
        if n >= 4 {
            let la = [a[0], a[1], a[2], a[3]];
            let lb = [b[0], b[1], b[2], b[3]];
            let want: u32 = (0..4).map(|i| ham16(la[i], lb[i])).sum();
            assert_eq!(ham16_packed(pack4(la), pack4(lb)), want);
            let want_m: u32 = (0..4).map(|i| ham16_masked(la[i], lb[i], mask)).sum();
            assert_eq!(
                ham16_packed_masked(pack4(la), pack4(lb), broadcast_mask(mask)),
                want_m
            );
        }
    });
}

#[test]
fn wavefront_sim_equals_seed_reference_sim() {
    // The fast engine (wavefront-bounded MAC loop + lane-major register
    // replay for WS; lane replay + flat slot loop for OS) must reproduce
    // the literal per-cycle simulator's counts AND functional output
    // bit-for-bit, for every coding configuration.
    check("fast sim == literal sim (all configs)", 12, |rng| {
        let (m, k, n) = (1 + rng.below(12), 1 + rng.below(32), 1 + rng.below(12));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.5;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for cfg in all_configs() {
            for df in [WS, OS] {
                let fast = simulate_tile(&t, &cfg, df);
                let golden = simulate_tile_reference(&t, &cfg, df);
                assert_eq!(
                    fast.counts, golden.counts,
                    "counts diverge: cfg {cfg:?} {df} tile {m}x{k}x{n}"
                );
                assert_eq!(
                    fast.c, golden.c,
                    "outputs diverge: cfg {cfg:?} {df} tile {m}x{k}x{n}"
                );
            }
        }
    });
}

#[test]
fn wavefront_sim_equals_reference_on_degenerate_geometries() {
    // Skinny/degenerate tiles stress the wavefront band arithmetic
    // (1-wide arrays, K=1 streams, K >> M+N streams) — per dataflow.
    let mut rng = Rng64::new(0xF00D);
    for (m, k, n) in [
        (1, 1, 1),
        (1, 64, 1),
        (16, 1, 16),
        (1, 40, 9),
        (9, 40, 1),
        (2, 100, 3),
    ] {
        let t = random_tile(&mut rng, m, k, n, 0.5, 0.2);
        for cfg in all_configs() {
            for df in [WS, OS] {
                let fast = simulate_tile(&t, &cfg, df);
                let golden = simulate_tile_reference(&t, &cfg, df);
                assert_eq!(fast.counts, golden.counts, "{m}x{k}x{n} cfg {cfg:?} {df}");
                assert_eq!(fast.c, golden.c, "{m}x{k}x{n} cfg {cfg:?} {df}");
            }
        }
    }
}

#[test]
fn input_zero_frac_stays_in_unit_interval() {
    // Regression for the PR 2 zero-GEMM guard, now asserted across both
    // dataflows and a degenerate (0-channel depthwise) layer: the
    // reported input zero fraction is always a finite value in [0, 1].
    use sa_lowpower::engine::SaEngine;
    use sa_lowpower::workload::{tinycnn, Layer, Network};
    let mut net = tinycnn();
    net.layers.push(Layer::depthwise("dw-degenerate", 0, 1, 8));
    let net = Network { name: "tinycnn+dw0".into(), layers: net.layers };
    for df in [WS, OS] {
        let sweep = SaEngine::builder()
            .max_tiles_per_layer(2)
            .dataflow(df)
            .threads(2)
            .build()
            .unwrap()
            .sweep(&net)
            .unwrap();
        for l in &sweep.layers {
            assert!(
                l.input_zero_frac.is_finite()
                    && (0.0..=1.0).contains(&l.input_zero_frac),
                "{df} layer {}: zero frac {}",
                l.layer_name,
                l.input_zero_frac
            );
        }
    }
}

#[test]
fn stream_toggles_packed_path_matches_pairwise_walk() {
    // stream_toggles now routes through ham16_slice on shifted slices;
    // it must stay identical to the scalar pairwise walk from any reset.
    check("packed stream_toggles == scalar walk", 200, |rng| {
        let n = rng.below(90);
        let reset = Bf16::from_bits(rng.next_u32() as u16);
        let s: Vec<Bf16> = (0..n)
            .map(|_| Bf16::from_bits(rng.next_u32() as u16))
            .collect();
        let mut want = 0u64;
        let mut prev = reset.0;
        for v in &s {
            want += (prev ^ v.0).count_ones() as u64;
            prev = v.0;
        }
        assert_eq!(stream_toggles(reset, &s), want);
    });
}


// ---- codec-stack satellite properties --------------------------------

#[test]
fn per_codec_decode_encode_identity_on_arbitrary_streams() {
    // decode∘encode is the identity on arbitrary bf16 streams, for every
    // registered codec — and gating happens exactly on zeros.
    use sa_lowpower::coding::{codec_by_name, known_codec_names, CodedWord};
    check("decode∘encode identity per codec", 60, |rng| {
        for name in known_codec_names() {
            let codec = codec_by_name(&name).unwrap();
            let mut lane = codec.begin();
            for _ in 0..48 {
                let v = Bf16::from_bits(rng.next_u32() as u16);
                match lane.encode(v) {
                    CodedWord::Gated => assert!(v.is_zero(), "{name}"),
                    CodedWord::Tx { word, sideband } => {
                        assert_eq!(codec.decode(word, sideband).0, v.0, "{name}");
                    }
                }
            }
        }
    });
}

/// Stream-side ledger view (everything charged to the two stream edges).
fn stream_side(c: &sa_lowpower::activity::ActivityCounts) -> [u64; 15] {
    [
        c.west_data_toggles,
        c.west_clock_events,
        c.west_sideband_toggles,
        c.west_sideband_clock_events,
        c.zero_detect_ops,
        c.west_cg_cell_cycles,
        c.west_comparator_bit_cycles,
        c.north_data_toggles,
        c.north_clock_events,
        c.north_sideband_toggles,
        c.north_sideband_clock_events,
        c.encoder_ops,
        c.decoder_toggles,
        c.north_cg_cell_cycles,
        c.north_comparator_bit_cycles,
    ]
}

#[test]
fn stack_charge_is_additive_across_edges() {
    // The two edges are independent lane families: the stream-side
    // charge of {w:X, i:Y} equals the charge of {w:X} plus the charge
    // of {i:Y} (baseline contributes zero overhead), on both backends.
    check("edge charges add", 15, |rng| {
        let (m, k, n) = (1 + rng.below(8), 1 + rng.below(24), 1 + rng.below(8));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for (w, i) in [
            ("bic-mantissa", "zvcg"),
            ("zvcg+bic-full", "ddcg16-g4"),
            ("ddcg16-g8", "zvcg+bic-segmented"),
        ] {
            let combined = stack(&format!("w:{w},i:{i}"));
            let w_only = stack(&format!("w:{w}"));
            let i_only = stack(&format!("i:{i}"));
            for df in [WS, OS] {
                for backend in
                    [&AnalyticBackend as &dyn EstimatorBackend, &CycleBackend]
                {
                    let both =
                        stream_side(&backend.estimate(&t, &combined, df).unwrap());
                    let ws = stream_side(&backend.estimate(&t, &w_only, df).unwrap());
                    let is = stream_side(&backend.estimate(&t, &i_only, df).unwrap());
                    let base = stream_side(
                        &backend.estimate(&t, &CodingStack::baseline(), df).unwrap(),
                    );
                    for f in 0..both.len() {
                        assert_eq!(
                            both[f],
                            ws[f] + is[f] - base[f],
                            "field {f}, w:{w} i:{i} {df} {}",
                            backend.name()
                        );
                    }
                }
            }
        }
    });
}

#[test]
fn commuting_codec_orders_charge_identically() {
    // Where codecs commute (a register clock gate is position-independent
    // relative to gating/coding), the stack's charge is order-stable:
    // both accepted orders produce the identical full ledger.
    check("order-stable charge for commuting codecs", 15, |rng| {
        let (m, k, n) = (1 + rng.below(7), 1 + rng.below(20), 1 + rng.below(7));
        let pz_a = rng.uniform();
        let pz_b = rng.uniform() * 0.4;
        let t = random_tile(rng, m, k, n, pz_a, pz_b);
        for (a, b) in [
            ("w:bic-mantissa+ddcg16-g4", "w:ddcg16-g4+bic-mantissa"),
            ("i:zvcg+ddcg16-g2", "i:ddcg16-g2+zvcg"),
            (
                "w:zvcg+bic-full+ddcg16-g8,i:zvcg",
                "w:ddcg16-g8+zvcg+bic-full,i:zvcg",
            ),
        ] {
            let sa = stack(a);
            let sb = stack(b);
            for df in [WS, OS] {
                let ca = AnalyticBackend.estimate(&t, &sa, df).unwrap();
                let cb = AnalyticBackend.estimate(&t, &sb, df).unwrap();
                assert_eq!(ca, cb, "'{a}' vs '{b}' {df}");
                let cyc_a = CycleBackend.estimate(&t, &sa, df).unwrap();
                assert_eq!(cyc_a, ca, "'{a}' cycle vs analytic {df}");
            }
        }
    });
}

#[test]
fn bic_never_increases_hamming_per_stack() {
    // The satellite form of the BIC bound: appending a BIC codec to ANY
    // base stack (empty, gated, clock-gated, or both) may only lower or
    // keep that edge's data-line toggles, per dataflow.
    check("BIC Hamming bound holds per stack", 12, |rng| {
        let t = random_tile(rng, 6, 40, 6, 0.3, 0.1);
        for base in ["", "zvcg", "ddcg16-g4", "zvcg+ddcg16-g2"] {
            for bic in ["bic-mantissa", "bic-full", "bic-segmented", "bic-exponent"]
            {
                let without = if base.is_empty() {
                    CodingStack::baseline()
                } else {
                    stack(&format!("w:{base}"))
                };
                let spec = if base.is_empty() {
                    format!("w:{bic}")
                } else {
                    // keep the valid order: gate, then code, then clock-gate
                    let with_bic = match base {
                        "zvcg" => format!("zvcg+{bic}"),
                        "ddcg16-g4" => format!("{bic}+ddcg16-g4"),
                        "zvcg+ddcg16-g2" => format!("zvcg+{bic}+ddcg16-g2"),
                        _ => unreachable!(),
                    };
                    format!("w:{with_bic}")
                };
                let with = stack(&spec);
                for df in [WS, OS] {
                    let c_without = analyze_tile(&t, &without, df);
                    let c_with = analyze_tile(&t, &with, df);
                    assert!(
                        c_with.north_data_toggles <= c_without.north_data_toggles,
                        "base '{base}' + {bic} {df}: {} > {}",
                        c_with.north_data_toggles,
                        c_without.north_data_toggles
                    );
                }
            }
        }
    });
}

#[test]
fn bf16_rounding_is_nearest() {
    check("bf16 RNE == nearest neighbour in f64", 3000, |rng| {
        let x = f32::from_bits(rng.next_u32());
        if x.is_nan() || x.is_infinite() {
            return;
        }
        let got = Bf16::from_f32(x);
        let up = Bf16::from_bits(got.to_bits().wrapping_add(1));
        let down = Bf16::from_bits(got.to_bits().wrapping_sub(1));
        let d = (x as f64 - got.to_f32() as f64).abs();
        for nb in [up, down] {
            if nb.is_nan() || nb.to_f32().is_infinite() {
                continue;
            }
            let dn = (x as f64 - nb.to_f32() as f64).abs();
            assert!(d <= dn, "x={x}: {got:?} vs {nb:?}");
        }
    });
}

//! Integration: workload tables × analysis engine × report emitters.

use sa_lowpower::engine::{ConfigSet, SaEngine};
use sa_lowpower::report::{ablation_table, fig45_table, headline_table};
use sa_lowpower::sa::SaConfig;
use sa_lowpower::stats::WeightFieldStats;
use sa_lowpower::workload::{gen_weights, Network};

fn fast_engine(configs: ConfigSet, threads: usize) -> SaEngine {
    SaEngine::builder()
        .max_tiles_per_layer(2)
        .configs(configs)
        .threads(threads)
        .build()
        .unwrap()
}

#[test]
fn fig2_distribution_claims_hold_for_both_networks() {
    // The statistical foundation of the paper's selective coding, on the
    // full synthetic weight sets of both evaluated networks.
    for name in ["resnet50", "mobilenet"] {
        let net = Network::by_name(name).unwrap();
        let mut all = Vec::new();
        for (i, l) in net.layers.iter().enumerate() {
            all.extend(gen_weights(l, 0xCAFE, i));
        }
        let s = WeightFieldStats::from_f32(&all);
        assert!(
            s.exponent_concentration(8) > 0.8,
            "{name}: exponent concentration {}",
            s.exponent_concentration(8)
        );
        assert!(
            s.mantissa_uniformity() > 0.95,
            "{name}: mantissa uniformity {}",
            s.mantissa_uniformity()
        );
        assert!(s.mantissa_expected_hamming() > 3.0, "{name}");
        assert!(s.exponent_expected_hamming() < 2.0, "{name}");
    }
}

#[test]
fn every_resnet_layer_analyzes_cleanly() {
    let net = Network::by_name("resnet50").unwrap();
    let engine = fast_engine(ConfigSet::paper(), 1);
    for (i, layer) in net.layers.iter().enumerate() {
        let r = engine.analyze_layer(layer, i).unwrap();
        let base = r.energy_of("baseline").unwrap().total();
        let prop = r.energy_of("proposed").unwrap().total();
        assert!(base > 0.0, "layer {} base", layer.name);
        assert!(prop > 0.0, "layer {} prop", layer.name);
        assert!(
            r.input_zero_frac >= 0.0 && r.input_zero_frac < 1.0,
            "layer {}",
            layer.name
        );
    }
}

#[test]
fn mobilenet_sweep_produces_paper_shaped_results() {
    let net = Network::by_name("mobilenet").unwrap();
    let sweep = fast_engine(ConfigSet::paper(), 4).sweep(&net).unwrap();
    assert_eq!(sweep.layers.len(), net.layers.len());
    let overall = sweep.overall_savings_pct("baseline", "proposed");
    assert!(
        (2.0..25.0).contains(&overall),
        "overall savings {overall}% (paper: 6.2 %)"
    );
    let act = sweep.streaming_activity_reduction_pct("baseline", "proposed");
    assert!((15.0..45.0).contains(&act), "activity cut {act}% (paper ~29 %)");
}

#[test]
fn ablation_ordering_matches_paper_arguments() {
    // On CNN workloads the paper's design choices must be visible:
    //  * proposed >= bic-only and >= zvcg-only in savings (synergy);
    //  * exponent-only BIC saves less streaming activity than
    //    mantissa-only (Fig. 2 argument).
    let net = Network::by_name("tinycnn").unwrap();
    let sweep = fast_engine(ConfigSet::ablation(), 4).sweep(&net).unwrap();
    let base = sweep.total_energy("baseline");
    let e = |n: &str| sweep.total_energy(n);
    assert!(e("proposed") < base);
    assert!(e("proposed") <= e("bic-only") + 1e-9, "synergy vs bic-only");
    assert!(e("proposed") <= e("zvcg-only") + 1e-9, "synergy vs zvcg-only");
    // The Fig. 2 argument concerns the *weight* (North) pipelines: the
    // exponent field is concentrated, so exponent BIC must reduce North
    // data toggles less than mantissa BIC. (The bic-exponent/-full/
    // -segmented configs all keep ZVCG on, so total streaming activity
    // would conflate the input-side gating wins.)
    let north = |n: &str| -> u64 {
        sweep
            .layers
            .iter()
            .flat_map(|l| &l.results)
            .filter(|r| r.config_name == n)
            .map(|r| r.counts.north_data_toggles)
            .sum()
    };
    let base_n = north("baseline");
    let man_cut = base_n - north("bic-only");
    let exp_cut = base_n.saturating_sub(north("bic-exponent"));
    assert!(
        man_cut > 2 * exp_cut,
        "mantissa BIC cut {man_cut} must dominate exponent BIC cut {exp_cut}"
    );
}

#[test]
fn report_tables_render_for_real_sweeps() {
    let net = Network::by_name("tinycnn").unwrap();
    let sweep = fast_engine(ConfigSet::paper(), 2).sweep(&net).unwrap();
    let t = fig45_table(&sweep, &SaConfig::default());
    assert_eq!(t.rows.len(), net.layers.len());
    let csv = t.to_csv();
    assert!(csv.lines().count() == net.layers.len() + 1);

    let h = headline_table(&sweep, &sweep, &SaConfig::default());
    assert!(h.render().contains("paper"));

    let ablation_engine = fast_engine(ConfigSet::ablation(), 2);
    let names = ablation_engine.configs().names();
    let sweep2 = ablation_engine.sweep(&net).unwrap();
    let a = ablation_table(&sweep2, &names);
    assert_eq!(a.rows.len(), names.len());
}

#[test]
fn transformer_sweep_produces_dense_stream_results() {
    // The transformer workload's point: attention/projection streams are
    // dense, so ZVCG gates far less than on ReLU CNNs and the proposed
    // savings shrink — but must never go negative (BIC still helps).
    let net = Network::by_name("transformer").unwrap();
    let sweep = fast_engine(ConfigSet::paper(), 4).sweep(&net).unwrap();
    assert_eq!(sweep.layers.len(), net.layers.len());
    let overall = sweep.overall_savings_pct("baseline", "proposed");
    assert!(
        (0.0..15.0).contains(&overall),
        "transformer savings {overall}% should undercut the CNN band"
    );
    // dense layers report low zero fractions; the FFN down-projections
    // report post-activation sparsity
    let zf = |name: &str| {
        sweep
            .layers
            .iter()
            .find(|l| l.layer_name == name)
            .unwrap()
            .input_zero_frac
    };
    assert!(zf("blk1.attn.qk") < 0.15);
    assert!(zf("blk1.ffn.down") > 0.3);
}

#[test]
fn network_totals_are_stable() {
    // Guard the workload tables against accidental edits: MACs/params of
    // the two paper networks (see workload module tests for the bands).
    let r = Network::by_name("resnet50").unwrap();
    let m = Network::by_name("mobilenet").unwrap();
    assert_eq!(r.layers.len(), 54);
    assert_eq!(m.layers.len(), 28);
    assert!(r.total_macs() > 6 * m.total_macs(), "resnet ~7x mobilenet MACs");
}

//! Drill every `sa-lint` rule against the deliberately-violating
//! corpus under `tests/lint_fixtures/`, proving (a) each rule fires at
//! exactly the expected lines, (b) pragma suppression works per rule,
//! and (c) the real tree is clean.
//!
//! Fixtures are *read*, never compiled: each is lexed under a synthetic
//! repo path chosen to land inside the rule's scope (e.g.
//! `rust/src/engine/…`).

use std::path::Path;

use sa_lowpower::lint::{load_repo, render_human, run, Finding, LintContext, SourceFile};

fn fixture(name: &str) -> String {
    let p = format!("{}/tests/lint_fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("{p}: {e}"))
}

fn ctx_one(path: &str, text: &str) -> LintContext {
    LintContext {
        files: vec![SourceFile::parse(path, text)],
        ..LintContext::default()
    }
}

/// Lines of `out` findings carrying `rule`, sorted.
fn lines(out: &[Finding], rule: &str) -> Vec<u32> {
    let mut v: Vec<u32> =
        out.iter().filter(|f| f.rule == rule).map(|f| f.line).collect();
    v.sort_unstable();
    v
}

/// Insert `inserted` as a new line *before* 1-based `line`.
fn insert_before(text: &str, line: u32, inserted: &str) -> String {
    let mut out = String::new();
    for (i, l) in text.lines().enumerate() {
        if i as u32 + 1 == line {
            out.push_str(inserted);
            out.push('\n');
        }
        out.push_str(l);
        out.push('\n');
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 1: no-panic-path
// ---------------------------------------------------------------------------

#[test]
fn no_panic_path_fires_on_each_form_and_respects_pragma_and_tests() {
    let text = fixture("no_panic_path.rs");
    let out = run(&ctx_one("rust/src/engine/fixture.rs", &text));
    // unwrap / expect / panic! / unreachable!; the pragma'd unwrap (24)
    // and the #[cfg(test)] unwrap are silent.
    assert_eq!(lines(&out, "no-panic-path"), vec![7, 11, 15, 19], "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "no-panic-path"), "{out:#?}");
}

#[test]
fn no_panic_path_is_scoped_to_engine_coordinator_sa() {
    let text = fixture("no_panic_path.rs");
    // Same violations under util/ are out of scope.
    let out = run(&ctx_one("rust/src/util/fixture.rs", &text));
    assert!(out.is_empty(), "{out:#?}");
}

// ---------------------------------------------------------------------------
// Rule 2: raw-lock
// ---------------------------------------------------------------------------

#[test]
fn raw_lock_fires_outside_lock_recover_and_respects_pragma() {
    let text = fixture("raw_lock.rs");
    let out = run(&ctx_one("rust/src/engine/fixture.rs", &text));
    // Line 7 fires; line 13 is inside fn lock_recover (exempt); line 18
    // is pragma'd.
    assert_eq!(lines(&out, "raw-lock"), vec![7], "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "raw-lock"), "{out:#?}");
}

// ---------------------------------------------------------------------------
// Rule 3: io-under-lock
// ---------------------------------------------------------------------------

#[test]
fn io_under_lock_fires_while_guard_held_and_clears_on_drop() {
    let text = fixture("io_under_lock.rs");
    let out = run(&ctx_one("rust/src/engine/fixture.rs", &text));
    // Line 10: File:: open under the guard. Line 11: drop(engine) under
    // the guard. Lines 13-14 (after drop(g)) and the pragma'd line 20
    // are silent.
    assert_eq!(lines(&out, "io-under-lock"), vec![10, 11], "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "io-under-lock"), "{out:#?}");
}

// ---------------------------------------------------------------------------
// Rule 4: catch-unwind-guard
// ---------------------------------------------------------------------------

#[test]
fn catch_unwind_guard_fires_unguarded_and_skips_imports() {
    let text = fixture("catch_unwind_guard.rs");
    let out = run(&ctx_one("rust/src/engine/fixture.rs", &text));
    // Line 11 (fn bare) fires; the import (8), the guarded fn (16) and
    // the pragma'd call (21) are silent.
    assert_eq!(lines(&out, "catch-unwind-guard"), vec![11], "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "catch-unwind-guard"), "{out:#?}");
}

// ---------------------------------------------------------------------------
// Rule 5: schema-tags
// ---------------------------------------------------------------------------

fn schema_ctx(src_text: &str) -> LintContext {
    let mut ctx = ctx_one("rust/src/fixture.rs", src_text);
    ctx.goldens.push((
        "rust/tests/golden/fixture.json".to_string(),
        concat!(
            "{\n",
            "  \"schema\": \"sa-lowpower.fixture-pinned.v1\",\n",
            "  \"orphan\": \"sa-lowpower.fixture-orphan.v3\"\n",
            "}\n"
        )
        .to_string(),
    ));
    ctx
}

#[test]
fn schema_tags_flags_ghost_and_orphan_but_not_pinned() {
    let text = fixture("schema_tags.rs");
    let out = run(&schema_ctx(&text));
    assert_eq!(out.len(), 2, "{out:#?}");
    // Ghost: emitted by src, pinned nowhere — flagged at the const.
    let ghost = &out[0];
    assert_eq!(ghost.rule, "schema-tags");
    assert_eq!(ghost.file, "rust/src/fixture.rs");
    assert_eq!(ghost.line, 8);
    assert!(ghost.why.contains("fixture-ghost.v2"), "{ghost:#?}");
    // Orphan: pinned by the golden, produced by no src string.
    let orphan = &out[1];
    assert_eq!(orphan.rule, "schema-tags");
    assert_eq!(orphan.file, "rust/tests/golden/fixture.json");
    assert!(orphan.why.contains("fixture-orphan.v3"), "{orphan:#?}");
}

#[test]
fn schema_tags_pragma_suppresses_the_src_side() {
    let text = fixture("schema_tags.rs");
    let patched = insert_before(
        &text,
        8,
        "// sa-lint: allow(schema-tags) reason=\"fixture proves pragma suppression\"",
    );
    let out = run(&schema_ctx(&patched));
    // Only the golden-side orphan survives (goldens carry no pragmas).
    assert_eq!(out.len(), 1, "{out:#?}");
    assert_eq!(out[0].file, "rust/tests/golden/fixture.json");
}

// ---------------------------------------------------------------------------
// Rule 6: error-table-sync
// ---------------------------------------------------------------------------

const FIXTURE_README: &str = "\
# Errors

| variant | kind | exit |
|---|---|---|
| `InvalidSpec` | `invalid-spec` | 2 |
| `Timeout` | `timeout` | 7 |
| `Internal` | `internal` | 9 |
";

fn error_ctx(src_text: &str) -> LintContext {
    let mut ctx = ctx_one("rust/src/engine/error.rs", src_text);
    ctx.readme = Some(("README.md".to_string(), FIXTURE_README.to_string()));
    ctx
}

#[test]
fn error_table_sync_flags_missing_arm_and_readme_drift() {
    let text = fixture("error_table.rs");
    let out = run(&error_ctx(&text));
    assert_eq!(out.len(), 2, "{out:#?}");
    // `Timeout` (line 9) has an exit_code() arm but no kind() arm.
    assert_eq!(out[0].rule, "error-table-sync");
    assert_eq!(out[0].file, "README.md");
    assert_eq!(out[0].line, 7, "README `Internal` row carries exit 9, code says 10");
    assert!(out[0].why.contains("exit code"), "{out:#?}");
    assert_eq!(out[1].rule, "error-table-sync");
    assert_eq!(out[1].file, "rust/src/engine/error.rs");
    assert_eq!(out[1].line, 9);
    assert!(out[1].why.contains("no kind() arm"), "{out:#?}");
}

#[test]
fn error_table_sync_pragma_suppresses_the_variant_finding() {
    let text = fixture("error_table.rs");
    let patched = insert_before(
        &text,
        9,
        "    // sa-lint: allow(error-table-sync) reason=\"fixture proves pragma suppression\"",
    );
    let out = run(&error_ctx(&patched));
    // Only the README drift survives (the README carries no pragmas).
    assert_eq!(out.len(), 1, "{out:#?}");
    assert_eq!(out[0].file, "README.md");
}

// ---------------------------------------------------------------------------
// Rule 7: registry-hygiene
// ---------------------------------------------------------------------------

#[test]
fn registry_hygiene_flags_duplicate_alias_and_bad_spec() {
    let text = fixture("registry.rs");
    let out = run(&ctx_one("rust/src/engine/registry.rs", &text));
    // Line 16: alias `base` duplicates line 15's. Line 17: spec
    // `w:frobnicate` fails the grammar check. The `name:` fn param in
    // by_name must NOT be read as a table row.
    assert_eq!(lines(&out, "registry-hygiene"), vec![16, 17], "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "registry-hygiene"), "{out:#?}");
    assert!(out.iter().any(|f| f.why.contains("already used")), "{out:#?}");
    assert!(out.iter().any(|f| f.why.contains("frobnicate")), "{out:#?}");
}

#[test]
fn registry_hygiene_pragma_suppresses_per_line() {
    let text = fixture("registry.rs");
    let patched = insert_before(
        &text,
        16,
        "    // sa-lint: allow(registry-hygiene) reason=\"fixture proves pragma suppression\"",
    );
    let out = run(&ctx_one("rust/src/engine/registry.rs", &patched));
    // The duplicate-alias finding is suppressed; the bad spec (now on
    // line 18 after the insertion) still fires.
    assert_eq!(lines(&out, "registry-hygiene"), vec![18], "{out:#?}");
}

// ---------------------------------------------------------------------------
// Rule 8: test-registration
// ---------------------------------------------------------------------------

#[test]
fn test_registration_flags_testless_file_and_unregistered_bench() {
    let text = fixture("no_tests.rs");
    let path = "rust/tests/fixture_no_tests.rs";
    let mut ctx = ctx_one(path, &text);
    ctx.test_files.push(path.to_string());
    ctx.cargo_toml = Some((
        "rust/Cargo.toml".to_string(),
        "[package]\nname = \"sa-lowpower\"\n".to_string(),
    ));
    ctx.bench_files.push("ghost_bench".to_string());
    let out = run(&ctx);
    assert_eq!(out.len(), 2, "{out:#?}");
    assert!(
        out.iter().any(|f| f.rule == "test-registration"
            && f.file == "rust/Cargo.toml"
            && f.why.contains("ghost_bench")),
        "{out:#?}"
    );
    assert!(
        out.iter().any(|f| f.rule == "test-registration"
            && f.file == path
            && f.line == 1),
        "{out:#?}"
    );
}

#[test]
fn test_registration_pragma_on_line_one_suppresses() {
    let text = fixture("no_tests.rs");
    let patched = insert_before(
        &text,
        1,
        "// sa-lint: allow(test-registration) reason=\"fixture proves pragma suppression\"",
    );
    let path = "rust/tests/fixture_no_tests.rs";
    let mut ctx = ctx_one(path, &patched);
    ctx.test_files.push(path.to_string());
    let out = run(&ctx);
    assert!(out.is_empty(), "{out:#?}");
}

// ---------------------------------------------------------------------------
// Rule 9: kernel-registration
// ---------------------------------------------------------------------------

const FIXTURE_SHAPES: &str = "\
/// Fixture shape table (the `;` in the type annotation must not
/// terminate the initializer walk).
pub const KERNEL_SHAPES: [&str; 3] = [
    \"plain\",
    \"zvcg\",
    \"zvcg+bic\",
];
";

fn kernel_ctx(conformance: Option<&str>) -> LintContext {
    kernel_ctx_with(FIXTURE_SHAPES, conformance)
}

fn kernel_ctx_with(shapes: &str, conformance: Option<&str>) -> LintContext {
    let mut files =
        vec![SourceFile::parse("rust/src/coding/specialize.rs", shapes)];
    if let Some(text) = conformance {
        files.push(SourceFile::parse("rust/tests/conformance.rs", text));
    }
    LintContext { files, ..LintContext::default() }
}

#[test]
fn kernel_registration_flags_shapes_missing_from_conformance() {
    // The conformance fixture names two of the three shapes; the third
    // ("zvcg+bic", line 6 of the shape table) must be flagged. A
    // mention buried inside a longer literal does not count.
    let conf = "const S: [&str; 3] = [\"plain\", \"zvcg\", \"w:zvcg+bic-x\"];\n";
    let out = run(&kernel_ctx(Some(conf)));
    assert_eq!(lines(&out, "kernel-registration"), vec![6], "{out:#?}");
    assert!(out.iter().all(|f| f.rule == "kernel-registration"), "{out:#?}");
    assert!(out[0].why.contains("zvcg+bic"), "{out:#?}");
    assert_eq!(out[0].file, "rust/src/coding/specialize.rs");
}

#[test]
fn kernel_registration_clean_when_every_shape_is_named() {
    let conf = "const S: [&str; 3] = [\"plain\", \"zvcg\", \"zvcg+bic\"];\n";
    let out = run(&kernel_ctx(Some(conf)));
    assert!(out.is_empty(), "{out:#?}");
}

#[test]
fn kernel_registration_flags_every_shape_without_a_conformance_file() {
    let out = run(&kernel_ctx(None));
    assert_eq!(lines(&out, "kernel-registration"), vec![4, 5, 6], "{out:#?}");
}

#[test]
fn kernel_registration_pragma_suppresses_per_line() {
    let patched = insert_before(
        FIXTURE_SHAPES,
        6,
        "    // sa-lint: allow(kernel-registration) reason=\"fixture proves pragma suppression\"",
    );
    let conf = "const S: [&str; 2] = [\"plain\", \"zvcg\"];\n";
    let out = run(&kernel_ctx_with(&patched, Some(conf)));
    assert!(out.is_empty(), "{out:#?}");
}

// ---------------------------------------------------------------------------
// The real tree is clean
// ---------------------------------------------------------------------------

#[test]
fn the_real_tree_is_lint_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ has a parent");
    let ctx = load_repo(root).expect("walk the repo");
    assert!(
        ctx.files.len() > 20,
        "repo walk looks truncated: {} files",
        ctx.files.len()
    );
    let out = run(&ctx);
    assert!(
        out.is_empty(),
        "sa-lint findings on the real tree:\n{}",
        render_human(&out, ctx.files.len())
    );
}

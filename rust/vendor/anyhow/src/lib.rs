//! Minimal in-tree stand-in for the `anyhow` crate, providing exactly the
//! subset this workspace uses: [`Error`], [`Result`], the [`anyhow!`] and
//! [`bail!`] macros, and the [`Context`] extension trait for `Result` and
//! `Option`.
//!
//! The build environment is fully offline (no crates.io), so the real
//! crate cannot be fetched; this shim keeps the call sites source-
//! compatible. Semantics match where it matters:
//!
//! * `{e}` displays the outermost message, `{e:#}` the full context
//!   chain (`outer: inner: root`), `{e:?}` a `Caused by:` listing;
//! * `?` converts any `std::error::Error + Send + Sync + 'static`,
//!   retaining the typed value for [`Error::downcast_ref`];
//! * `Error` itself deliberately does **not** implement
//!   `std::error::Error`, mirroring anyhow, so the blanket `From` impl
//!   and the identity `From<Error>` never conflict.

use std::any::Any;
use std::fmt::{self, Display};

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a chain of context messages, outermost first, plus
/// (when converted from a typed error) the original value for
/// downcasting.
pub struct Error {
    /// chain[0] is the outermost context; the last entry is the root.
    chain: Vec<String>,
    /// The typed root error `?` converted this from, when any (message
    /// errors have none). Supports [`Error::downcast_ref`].
    payload: Option<Box<dyn Any + Send + Sync>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()], payload: None }
    }

    /// Wrap with an outer context message.
    pub fn context<C: Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The context chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }

    /// The typed root error this was converted from, if it was an `E`.
    /// Context wrapping does not erase the payload; errors built from
    /// bare messages (`anyhow!`) have none.
    pub fn downcast_ref<E: Any>(&self) -> Option<&E> {
        self.payload.as_ref().and_then(|p| p.downcast_ref::<E>())
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        // Preserve the std source chain as context entries.
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain, payload: Some(Box::new(e)) }
    }
}

/// Attach context to errors (and missing `Option` values).
pub trait Context<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T, E>
    for std::result::Result<T, E>
{
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`anyhow!`] error.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: gone");
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(format!("{}", inner().unwrap_err()), "gone");
    }

    #[test]
    fn macros_build_errors() {
        let name = "x";
        let e = anyhow!("unknown network '{name}'");
        assert_eq!(format!("{e}"), "unknown network 'x'");
        let from_string = anyhow!(String::from("plain"));
        assert_eq!(format!("{from_string}"), "plain");
        fn bails() -> Result<()> {
            bail!("bad {}", 7)
        }
        assert_eq!(format!("{}", bails().unwrap_err()), "bad 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(3).context("missing").unwrap(), 3);
    }

    #[test]
    fn error_is_send_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }

    #[test]
    fn downcast_ref_recovers_the_typed_root() {
        let e = Error::from(io_err());
        let io = e.downcast_ref::<std::io::Error>().expect("payload retained");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.downcast_ref::<std::fmt::Error>().is_none());
        // context wrapping keeps the payload; plain messages have none
        let wrapped = Error::from(io_err()).context("outer");
        assert!(wrapped.downcast_ref::<std::io::Error>().is_some());
        assert!(anyhow!("just text").downcast_ref::<std::io::Error>().is_none());
    }
}

//! Offline stub of the PJRT/XLA binding surface used by
//! `sa_lowpower::runtime`.
//!
//! The real bindings (PJRT CPU plugin + HLO parsing) are not available in
//! this offline build image, so this crate provides the same types and
//! signatures but fails at the **compile** step with a clear
//! "backend unavailable" error. Everything upstream of compilation
//! (manifest loading, literal packing/validation) works, and everything
//! downstream is unreachable without a compiled executable. The
//! artifact-driven integration tests skip themselves when `artifacts/`
//! is absent, so the stub keeps `cargo test` green while preserving the
//! full runtime code path for images that ship real PJRT.

use std::fmt;
use std::path::Path;

/// Error type of the binding layer.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT backend not available in this offline build \
         (the xla crate is the in-tree stub)"
    ))
}

/// Element types a literal can hold (the subset the runtime moves).
#[derive(Clone, Debug, PartialEq)]
enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host-side tensor literal.
#[derive(Clone, Debug, PartialEq)]
pub struct Literal {
    data: Data,
    dims: Vec<i64>,
}

/// Conversion trait for `Literal::to_vec::<T>()`.
pub trait NativeType: Sized {
    fn extract(lit: &Literal) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    fn extract(lit: &Literal) -> Result<Vec<f32>> {
        match &lit.data {
            Data::F32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not f32".into())),
        }
    }
}

impl NativeType for i32 {
    fn extract(lit: &Literal) -> Result<Vec<i32>> {
        match &lit.data {
            Data::I32(v) => Ok(v.clone()),
            _ => Err(Error("literal is not i32".into())),
        }
    }
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: Data::F32(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Reshape, validating the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product::<i64>().max(1);
        let have = match &self.data {
            Data::F32(v) => v.len() as i64,
            Data::I32(v) => v.len() as i64,
            Data::Tuple(_) => return Err(Error("cannot reshape a tuple".into())),
        };
        if want != have.max(1) {
            return Err(Error(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Copy out as a host vector of `T`.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::extract(self)
    }
}

/// Parsed HLO module (stub: retains nothing but provenance).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    source: String,
}

impl HloModuleProto {
    /// Parse HLO text from a file. The stub only checks readability;
    /// real parsing happens in the non-stub bindings.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        let p = path.as_ref();
        std::fs::read_to_string(p)
            .map(|_| HloModuleProto { source: p.display().to_string() })
            .map_err(|e| Error(format!("reading HLO text {p:?}: {e}")))
    }
}

/// An XLA computation handle.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    source: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { source: proto.source.clone() }
    }
}

/// A compiled, device-loaded executable. Not constructible through the
/// stub (compilation always fails), but the type keeps callers compiling.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

/// A device buffer returned by an execution.
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute"))
    }
}

/// The PJRT client. The stub client constructs (so manifest-only flows
/// work) but cannot compile.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Create the CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable(&format!("compile('{}')", comp.source)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(r.to_vec::<i32>().is_err());
        assert!(l.reshape(&[3, 3]).is_err());
    }

    #[test]
    fn client_constructs_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub-cpu");
        let proto = HloModuleProto { source: "x".into() };
        let comp = XlaComputation::from_proto(&proto);
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("not available"));
    }
}

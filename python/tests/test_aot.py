"""AOT path: every entry point lowers to parseable HLO text, the manifest
round-trips, and the lowered gemm matches the eager kernel numerically
(compile-consistency check through XLA itself)."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_entry_points_lower(tmp_path):
    lines = aot.lower_all(str(tmp_path))
    names = {ln.split()[0].split("=")[1] for ln in lines}
    assert names == {
        "tinycnn_forward",
        "gemm_256",
        "gemm_zero_skip_256",
        "weight_stats",
        "activity_stats",
    }
    for name, fn, _ in aot.entry_points():
        path = tmp_path / f"{name}.hlo.txt"
        assert path.exists()
        text = path.read_text()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_format(tmp_path):
    lines = aot.lower_all(str(tmp_path))
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest == lines
    for ln in manifest:
        fields = dict(kv.split("=", 1) for kv in ln.split())
        assert set(fields) == {"name", "file", "inputs", "outputs"}
        for io in ("inputs", "outputs"):
            for aval in fields[io].split(";"):
                dt, dims = aval.split("[")
                assert dt in ("f32", "float32", "int32", "i32")
                assert dims.endswith("]")


def test_gemm_artifact_consistency():
    """The jitted/lowerable gemm equals the eager Pallas kernel."""
    r = np.random.default_rng(0)
    a = r.standard_normal((aot.GEMM_DIM, aot.GEMM_DIM)).astype(np.float32)
    b = r.standard_normal((aot.GEMM_DIM, aot.GEMM_DIM)).astype(np.float32)
    jitted = jax.jit(model.gemm)(a, b)
    eager = model.gemm(a, b)
    np.testing.assert_allclose(np.asarray(jitted), np.asarray(eager), rtol=1e-6)


def test_zero_skip_artifact_equivalence():
    r = np.random.default_rng(1)
    a = r.standard_normal((aot.GEMM_DIM, aot.GEMM_DIM)).astype(np.float32)
    a[:64] = 0.0  # entire zero tiles
    b = r.standard_normal((aot.GEMM_DIM, aot.GEMM_DIM)).astype(np.float32)
    base = np.asarray(jax.jit(model.gemm)(a, b))
    skip = np.asarray(jax.jit(model.gemm_zero_skip)(a, b))
    np.testing.assert_array_equal(base, skip)

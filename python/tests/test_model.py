"""L2 model correctness: im2col conv vs lax conv, TinyConvNet invariants,
weight statistics oracle."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given

from compile import model
from compile.kernels.ref import conv2d_ref


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# conv2d (im2col + Pallas GEMM) vs lax.conv oracle
# ---------------------------------------------------------------------------


@given(
    h=st.integers(4, 14),
    w=st.integers(4, 14),
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5]),
    stride=st.sampled_from([1, 2]),
    padding=st.sampled_from(["SAME", "VALID"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_matches_lax(h, w, cin, cout, k, stride, padding, seed):
    if padding == "VALID" and (h < k or w < k):
        return
    r = _rng(seed)
    x = r.standard_normal((1, h, w, cin)).astype(np.float32)
    wgt = (r.standard_normal((k, k, cin, cout)) * 0.2).astype(np.float32)
    got = model.conv2d(x, wgt, stride=stride, padding=padding)
    want = conv2d_ref(x, wgt, stride, padding)
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-3)


def test_im2col_ordering():
    """Patch features must be ordered (kh, kw, c) — the rust lowering
    (workload/im2col.rs) depends on this exact ordering."""
    x = np.arange(2 * 2 * 2, dtype=np.float32).reshape(1, 2, 2, 2)
    p = np.asarray(model.im2col(jnp.asarray(x), 2, 2, 1))
    assert p.shape == (1, 8)
    # row-major over (kh, kw, c): x[0,0,0,:], x[0,0,1,:], x[0,1,0,:], x[0,1,1,:]
    np.testing.assert_array_equal(p[0], np.arange(8, dtype=np.float32))


def test_conv2d_channel_mismatch_raises():
    x = np.zeros((1, 8, 8, 3), np.float32)
    w = np.zeros((3, 3, 4, 8), np.float32)
    with pytest.raises(AssertionError):
        model.conv2d(x, w)


# ---------------------------------------------------------------------------
# TinyConvNet
# ---------------------------------------------------------------------------


def _tiny_params(seed=7):
    r = _rng(seed)
    params = []
    for shp in model.tinycnn_param_shapes():
        fan_in = int(np.prod(shp[:-1])) if len(shp) > 1 else shp[0]
        params.append(
            (r.standard_normal(shp) * np.sqrt(2.0 / max(fan_in, 1))).astype(
                np.float32
            )
        )
    return params


def test_tinycnn_shapes():
    params = _tiny_params()
    x = _rng(0).random(model.TINYCNN_INPUT).astype(np.float32)
    outs = model.tinycnn_forward(x, *params)
    logits, acts = outs[0], outs[1:]
    assert logits.shape == (1, model.TINYCNN_CLASSES)
    assert len(acts) == len(model.TINYCNN_CONVS)
    # SAME padding: spatial halves at the two stride-2 layers
    assert acts[0].shape == (1, 32, 32, 16)
    assert acts[1].shape == (1, 16, 16, 32)
    assert acts[2].shape == (1, 16, 16, 32)
    assert acts[3].shape == (1, 8, 8, 64)
    assert acts[4].shape == (1, 8, 8, 64)


def test_tinycnn_relu_invariants():
    params = _tiny_params(11)
    x = _rng(1).random(model.TINYCNN_INPUT).astype(np.float32)
    outs = model.tinycnn_forward(x, *params)
    for i, a in enumerate(outs[1:]):
        a = np.asarray(a)
        assert (a >= 0).all(), f"act {i} has negative values after ReLU"
        zfrac = float((a == 0).mean())
        # ReLU of a roughly-centered pre-activation: a meaningful fraction
        # of zeros must appear (this drives the paper's ZVCG technique).
        assert 0.05 < zfrac < 0.95, f"act {i} zero fraction {zfrac}"


def test_tinycnn_deterministic():
    params = _tiny_params(3)
    x = _rng(2).random(model.TINYCNN_INPUT).astype(np.float32)
    o1 = model.tinycnn_forward(x, *params)
    o2 = model.tinycnn_forward(x, *params)
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# weight statistics (Fig. 2 oracle)
# ---------------------------------------------------------------------------


def test_weight_stats_totals():
    r = _rng(5)
    w = (r.standard_normal(4096) * 0.05).astype(np.float32)
    exp_h, man_h, zeros, total = model.weight_stats(w)
    assert int(total) == 4096
    assert int(np.asarray(exp_h).sum()) == 4096
    assert int(np.asarray(man_h).sum()) == 4096


def test_weight_stats_known_values():
    # 1.0 -> exp 127, man 0; 0.5 -> exp 126; 1.5 -> man 0x40; 0.0 -> zero
    w = np.array([1.0, 0.5, 1.5, 0.0], np.float32)
    exp_h, man_h, zeros, total = model.weight_stats(w)
    exp_h = np.asarray(exp_h)
    man_h = np.asarray(man_h)
    assert exp_h[127] == 2  # 1.0 and 1.5
    assert exp_h[126] == 1  # 0.5
    assert man_h[0x40] == 1  # 1.5
    assert int(zeros) == 1


def test_weight_stats_concentration_smallweights():
    """Fan-in-scaled weights: exponents concentrated (paper Fig. 2 top),
    mantissas near-uniform (paper Fig. 2 bottom)."""
    r = _rng(9)
    w = np.clip(r.standard_normal(1 << 15) * 0.08, -1, 1).astype(np.float32)
    exp_h, man_h, _, total = model.weight_stats(w)
    exp_h = np.asarray(exp_h).astype(np.float64)
    man_h = np.asarray(man_h).astype(np.float64)
    # exponent mass concentrated in a narrow band below the bias
    top8 = np.sort(exp_h)[-8:].sum() / exp_h.sum()
    assert top8 > 0.9, f"exponent concentration too weak: {top8}"
    # mantissa approximately uniform: no bin wildly over/under-represented
    p = man_h / man_h.sum()
    assert p.max() < 3.0 / 128 and p[p > 0].min() > 0.2 / 128

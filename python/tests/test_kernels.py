"""L1 kernel correctness: Pallas kernels vs pure-jnp oracles.

hypothesis sweeps shapes, dtypes, tile sizes and value patterns; every case
asserts allclose against ref.py. This is the CORE correctness signal for
the compute path that the AOT artifacts freeze.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile.kernels.activity import stream_activity
from compile.kernels.matmul import matmul_bf16
from compile.kernels.ref import matmul_ref, stream_activity_ref

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------


@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
    dtype=st.sampled_from([np.float32, np.float64]),
)
def test_matmul_matches_ref_shapes(m, k, n, seed, dtype):
    r = _rng(seed)
    a = r.standard_normal((m, k)).astype(dtype)
    b = r.standard_normal((k, n)).astype(dtype)
    got = matmul_bf16(a, b)
    want = matmul_ref(a, b)
    # bf16 products are exact in f32; only the f32 accumulation order
    # differs between the K-blocked kernel and the single jnp.dot.
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@given(
    tile=st.sampled_from([(8, 8, 8), (16, 16, 16), (16, 8, 32), (32, 32, 16)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_tile_invariance(tile, seed):
    """The result must not depend on the tiling (pure schedule change),
    up to f32 accumulation-order rounding."""
    r = _rng(seed)
    a = r.standard_normal((40, 56)).astype(np.float32)
    b = r.standard_normal((56, 24)).astype(np.float32)
    tm, tn, tk = tile
    got = matmul_bf16(a, b, tile_m=tm, tile_n=tn, tile_k=tk)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@given(
    sparsity=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_zero_skip_is_exact(sparsity, seed):
    """Zero-block skipping is a pure power optimization: results must be
    bit-identical to the non-skipping kernel, at any input sparsity."""
    r = _rng(seed)
    a = r.standard_normal((48, 64)).astype(np.float32)
    mask = r.random(a.shape) < sparsity
    a = np.where(mask, 0.0, a).astype(np.float32)
    b = r.standard_normal((64, 32)).astype(np.float32)
    base = np.asarray(matmul_bf16(a, b))
    skip = np.asarray(matmul_bf16(a, b, skip_zero_blocks=True))
    np.testing.assert_array_equal(base, skip)


def test_matmul_all_zero_a():
    a = np.zeros((16, 16), np.float32)
    b = np.ones((16, 16), np.float32)
    np.testing.assert_array_equal(
        np.asarray(matmul_bf16(a, b, skip_zero_blocks=True)), np.zeros((16, 16))
    )


def test_matmul_identity():
    a = np.eye(16, dtype=np.float32)
    b = np.arange(256, dtype=np.float32).reshape(16, 16)
    # bf16 can represent integers up to 256 exactly
    np.testing.assert_array_equal(np.asarray(matmul_bf16(a, b)), b)


def test_matmul_bf16_rounding_is_applied():
    """Inputs must be rounded to bf16 before multiplying (paper format)."""
    a = np.array([[1.0 + 2**-10]], np.float32)  # rounds to 1.0 in bf16
    b = np.array([[1.0]], np.float32)
    got = float(np.asarray(matmul_bf16(a, b))[0, 0])
    assert got == 1.0


def test_matmul_bad_shapes_raise():
    a = np.zeros((4, 5), np.float32)
    b = np.zeros((6, 4), np.float32)
    with pytest.raises(ValueError):
        matmul_bf16(a, b)


# ---------------------------------------------------------------------------
# activity kernel
# ---------------------------------------------------------------------------


@given(
    lanes=st.integers(1, 16),
    length=st.integers(2, 128),
    seed=st.integers(0, 2**31 - 1),
    sparsity=st.floats(0.0, 1.0),
)
def test_activity_matches_ref(lanes, length, seed, sparsity):
    r = _rng(seed)
    s = r.standard_normal((lanes, length)).astype(np.float32)
    s = np.where(r.random(s.shape) < sparsity, 0.0, s).astype(np.float32)
    got_t, got_z = stream_activity(s)
    want_t, want_z = stream_activity_ref(s)
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))
    np.testing.assert_array_equal(np.asarray(got_z), np.asarray(want_z))


def test_activity_constant_stream_has_no_toggles():
    s = np.full((4, 64), 0.5, np.float32)
    t, z = stream_activity(s)
    np.testing.assert_array_equal(np.asarray(t), np.zeros(4, np.int32))
    np.testing.assert_array_equal(np.asarray(z), np.zeros(4, np.int32))


def test_activity_counts_negative_zero_as_zero():
    """The paper's zero detector fires on magnitude zero; -0.0 qualifies."""
    s = np.array([[0.0, -0.0, 1.0, 0.0]], np.float32)
    _, z = stream_activity(s)
    assert int(np.asarray(z)[0]) == 3


def test_activity_known_toggle_count():
    # bf16(1.0) = 0x3F80, bf16(-1.0) = 0xBF80 -> 1 toggle (sign bit)
    s = np.array([[1.0, -1.0, 1.0]], np.float32)
    t, _ = stream_activity(s)
    assert int(np.asarray(t)[0]) == 2


def test_activity_hand_model():
    """Cross-check against a from-scratch numpy bit model (not jax)."""
    r = _rng(1234)
    s = r.standard_normal((3, 50)).astype(np.float32)
    bits = (
        jnp.asarray(s).astype(jnp.bfloat16).view(jnp.uint16)
    )
    bits = np.asarray(bits).astype(np.uint16)
    want = np.zeros(3, np.int64)
    for lane in range(3):
        for i in range(49):
            want[lane] += bin(int(bits[lane, i]) ^ int(bits[lane, i + 1])).count("1")
    t, _ = stream_activity(s)
    np.testing.assert_array_equal(np.asarray(t).astype(np.int64), want)


def test_activity_rejects_1d():
    with pytest.raises(ValueError):
        stream_activity(np.zeros(8, np.float32))

"""L2: JAX model layer — CNN building blocks on top of the L1 Pallas matmul.

Convolutions are lowered to GEMM by explicit im2col (the same lowering the
rust coordinator performs in rust/src/workload/im2col.rs), so that every
multiply-accumulate in the network flows through the Pallas output-
stationary matmul kernel — i.e. through the "systolic array" compute path.

The e2e demo network (TinyConvNet, 32x32 inputs) is deliberately small:
it is the functional workload of examples/e2e_inference.rs, where the rust
coordinator runs XLA inference and SA power analysis side by side. The
per-layer ReLU activations are returned so the coordinator can measure the
*emergent* zero fractions that drive the paper's zero-value clock gating.

Also defined here: the weight-statistics graph behind Fig. 2 (bf16
exponent/mantissa histograms) used to cross-check the rust stats module.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .kernels.activity import stream_activity
from .kernels.matmul import matmul_bf16


# ---------------------------------------------------------------------------
# im2col convolution on top of the Pallas matmul
# ---------------------------------------------------------------------------


def im2col(x: jax.Array, kh: int, kw: int, stride: int) -> jax.Array:
    """Explicit im2col: NHWC (pre-padded) -> (N*OH*OW, KH*KW*C) patches.

    Patch features are ordered (kh, kw, c), matching both the HWIO weight
    reshape below and the rust lowering (workload/im2col.rs) bit-for-bit.
    """
    n, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    slices = []
    for i in range(kh):
        for j in range(kw):
            s = x[:, i : i + oh * stride : stride, j : j + ow * stride : stride, :]
            slices.append(s)  # (n, oh, ow, c)
    patches = jnp.stack(slices, axis=3)  # (n, oh, ow, kh*kw, c)
    return patches.reshape(n * oh * ow, kh * kw * c)


def conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    stride: int = 1,
    padding: str = "SAME",
    skip_zero_blocks: bool = False,
) -> jax.Array:
    """NHWC x HWIO convolution via im2col + the Pallas bf16 GEMM."""
    n, h, wdt, c = x.shape
    kh, kw, ci, co = w.shape
    assert ci == c, f"channel mismatch {ci} vs {c}"
    if padding == "SAME":
        oh = -(-h // stride)
        ow = -(-wdt // stride)
        pad_h = max(0, (oh - 1) * stride + kh - h)
        pad_w = max(0, (ow - 1) * stride + kw - wdt)
        x = jnp.pad(
            x,
            (
                (0, 0),
                (pad_h // 2, pad_h - pad_h // 2),
                (pad_w // 2, pad_w - pad_w // 2),
                (0, 0),
            ),
        )
    elif padding != "VALID":
        raise ValueError(f"unsupported padding {padding!r}")
    _, hp, wp, _ = x.shape
    oh = (hp - kh) // stride + 1
    ow = (wp - kw) // stride + 1

    a = im2col(x, kh, kw, stride)  # (M, K) with M = n*oh*ow
    b = w.reshape(kh * kw * c, co)  # (K, N)
    y = matmul_bf16(a, b, skip_zero_blocks=skip_zero_blocks)
    return y.reshape(n, oh, ow, co)


# ---------------------------------------------------------------------------
# TinyConvNet: the e2e demo workload
# ---------------------------------------------------------------------------


class ConvSpec(NamedTuple):
    kh: int
    kw: int
    cin: int
    cout: int
    stride: int


# 32x32x3 input. Five conv layers + GAP + FC head. Matches
# rust/src/workload/tinycnn.rs layer-for-layer.
TINYCNN_CONVS: tuple[ConvSpec, ...] = (
    ConvSpec(3, 3, 3, 16, 1),
    ConvSpec(3, 3, 16, 32, 2),
    ConvSpec(3, 3, 32, 32, 1),
    ConvSpec(3, 3, 32, 64, 2),
    ConvSpec(3, 3, 64, 64, 1),
)
TINYCNN_CLASSES = 10
TINYCNN_INPUT = (1, 32, 32, 3)


def tinycnn_param_shapes() -> list[tuple[int, ...]]:
    """Shapes of the forward-pass parameters, in argument order."""
    shapes: list[tuple[int, ...]] = []
    for s in TINYCNN_CONVS:
        shapes.append((s.kh, s.kw, s.cin, s.cout))
    shapes.append((TINYCNN_CONVS[-1].cout, TINYCNN_CLASSES))  # fc weight
    shapes.append((TINYCNN_CLASSES,))  # fc bias
    return shapes


def tinycnn_forward(x: jax.Array, *params: jax.Array):
    """Forward pass. Returns (logits, act_1, ..., act_5).

    All conv GEMMs run through the Pallas kernel; per-layer post-ReLU
    activations are returned so the rust coordinator can measure emergent
    zero fractions (the input of the paper's zero-value clock gating).
    """
    assert len(params) == len(TINYCNN_CONVS) + 2
    conv_ws = params[: len(TINYCNN_CONVS)]
    fc_w, fc_b = params[-2], params[-1]

    acts = []
    h = x
    for spec, w in zip(TINYCNN_CONVS, conv_ws):
        h = conv2d(h, w, stride=spec.stride, padding="SAME")
        h = jax.nn.relu(h)
        acts.append(h)
    # Global average pool + FC head (also through the Pallas GEMM).
    g = jnp.mean(h, axis=(1, 2))  # (N, C)
    logits = matmul_bf16(g, fc_w) + fc_b
    return (logits, *acts)


# ---------------------------------------------------------------------------
# Statistics graphs (Fig. 2 cross-check + activity cross-check)
# ---------------------------------------------------------------------------


def weight_stats(w: jax.Array):
    """bf16 field histograms of a flat weight vector (Fig. 2 oracle).

    Returns (exp_hist[256], man_hist[128], zeros, total). Zero-magnitude
    values are excluded from the exponent histogram's "concentration"
    reading by being counted separately (exponent 0 with zero mantissa is
    the encoding of 0.0, not a small normal).
    """
    bits = jax.lax.bitcast_convert_type(w.astype(jnp.bfloat16), jnp.uint16)
    bits = bits.reshape(-1)
    exp = ((bits >> 7) & 0xFF).astype(jnp.int32)
    man = (bits & 0x7F).astype(jnp.int32)
    exp_hist = jnp.zeros(256, jnp.int32).at[exp].add(1)
    man_hist = jnp.zeros(128, jnp.int32).at[man].add(1)
    zeros = ((bits & 0x7FFF) == 0).astype(jnp.int32).sum()
    total = jnp.int32(bits.shape[0])
    return exp_hist, man_hist, zeros, total


def activity_stats(streams: jax.Array):
    """(toggles[lanes], zeros[lanes]) via the L1 activity kernel."""
    return stream_activity(streams)


# ---------------------------------------------------------------------------
# Standalone GEMM entry point (validation workload for the rust runtime)
# ---------------------------------------------------------------------------


def gemm(a: jax.Array, b: jax.Array) -> jax.Array:
    """Plain bf16 GEMM through the Pallas kernel (f32 in/out interface)."""
    return matmul_bf16(a, b)


def gemm_zero_skip(a: jax.Array, b: jax.Array) -> jax.Array:
    """GEMM with block-level zero skipping enabled (must be numerically
    identical to `gemm` — zero blocks contribute nothing)."""
    return matmul_bf16(a, b, skip_zero_blocks=True)

"""AOT compile path: lower every L2 entry point to HLO *text* artifacts.

This is the only place python touches the system; `make artifacts` runs it
once and the rust binary is self-contained afterwards.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the crate's xla_extension
0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Every artifact is lowered with return_tuple=True, so the rust side always
unwraps a tuple. All artifact interfaces are f32 (casts to bf16 happen
inside the graph) so the rust side never needs bf16 literal support.

A plain-text manifest (artifacts/manifest.txt) records, per artifact:
name, file, input shapes, output shapes — parsed by rust/src/runtime/.
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _fmt_aval(aval) -> str:
    dt = jnp.dtype(aval.dtype).name
    dims = ",".join(str(d) for d in aval.shape)
    return f"{dt}[{dims}]"


# Fixed shapes for the statistics cross-check artifacts. The rust side pads
# to these shapes and corrects the zero/exponent-0 counts for the padding.
WEIGHT_STATS_LEN = 16384
ACTIVITY_LANES = 16
ACTIVITY_LEN = 1024

GEMM_DIM = 256


def entry_points():
    """(name, fn, arg_specs) for every artifact."""
    x_spec = _spec(model.TINYCNN_INPUT)
    param_specs = [_spec(s) for s in model.tinycnn_param_shapes()]
    g = _spec((GEMM_DIM, GEMM_DIM))
    return [
        ("tinycnn_forward", model.tinycnn_forward, [x_spec, *param_specs]),
        ("gemm_256", model.gemm, [g, g]),
        ("gemm_zero_skip_256", model.gemm_zero_skip, [g, g]),
        ("weight_stats", model.weight_stats, [_spec((WEIGHT_STATS_LEN,))]),
        (
            "activity_stats",
            model.activity_stats,
            [_spec((ACTIVITY_LANES, ACTIVITY_LEN))],
        ),
    ]


def lower_all(outdir: str) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    manifest_lines = []
    for name, fn, specs in entry_points():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        outs = jax.tree_util.tree_leaves(out_avals)
        ins = ";".join(_fmt_aval(s) for s in specs)
        os_ = ";".join(_fmt_aval(o) for o in outs)
        manifest_lines.append(f"name={name} file={fname} inputs={ins} outputs={os_}")
        print(f"  {name}: {len(text)} chars, in=[{ins}] out=[{os_}]")
    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return manifest_lines


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--outdir", default="../artifacts")
    args = p.parse_args()
    print(f"lowering artifacts to {args.outdir}")
    lower_all(args.outdir)
    print("done")


if __name__ == "__main__":
    main()

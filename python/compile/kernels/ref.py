"""Pure-jnp oracles for the Pallas kernels (the L1 correctness signal).

Everything here is deliberately boring: plain jnp ops, no pallas, no
cleverness. pytest (python/tests/) asserts the kernels match these
references over hypothesis-generated shapes, dtypes and value patterns.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """bf16 x bf16 -> f32 matmul, the paper's PE arithmetic."""
    a = a.astype(jnp.bfloat16).astype(jnp.float32)
    b = b.astype(jnp.bfloat16).astype(jnp.float32)
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def stream_activity_ref(streams: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-lane (toggles, zeros) of a (lanes, length) bf16 stream matrix."""
    bits = jax.lax.bitcast_convert_type(
        streams.astype(jnp.bfloat16), jnp.uint16
    )
    x = bits[:, 1:] ^ bits[:, :-1]
    toggles = jax.lax.population_count(x).astype(jnp.int32).sum(axis=1)
    zeros = ((bits & jnp.uint16(0x7FFF)) == 0).astype(jnp.int32).sum(axis=1)
    return toggles, zeros


def conv2d_ref(
    x: jax.Array, w: jax.Array, stride: int, padding: str
) -> jax.Array:
    """NHWC x HWIO conv via lax.conv_general_dilated, bf16 operands."""
    xf = x.astype(jnp.bfloat16).astype(jnp.float32)
    wf = w.astype(jnp.bfloat16).astype(jnp.float32)
    return jax.lax.conv_general_dilated(
        xf,
        wf,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )

"""L1 Pallas kernel: switching-activity + zero measurement over bf16 streams.

The paper's entire claim is phrased in terms of the switching activity of
the value streams entering the systolic array (Hamming distance between
consecutive bus values) and the fraction of zero-valued inputs. This kernel
is the measurement hot-spot: given a (lanes, length) stream matrix of
bfloat16 values (one lane per SA row/column), it computes per lane

  * the total number of bit toggles between consecutive stream elements
    (sum of popcount(bits[t] ^ bits[t+1]))
  * the number of zero elements (+0.0 or -0.0, matching the paper's
    zero-detector which fires on magnitude zero).

It is used to cross-check the rust activity model (rust/src/activity/)
through the AOT artifact `activity_stats`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

def _activity_kernel(bits_ref, tog_ref, zer_ref):
    bits = bits_ref[...]
    x = bits[:, 1:] ^ bits[:, :-1]
    tog_ref[...] = jnp.sum(
        jax.lax.population_count(x).astype(jnp.int32), axis=1
    )
    # bf16 magnitude mask (everything but the sign bit), as a python int so
    # the kernel captures no traced constants (pallas lowering requirement).
    zer_ref[...] = jnp.sum(
        ((bits & 0x7FFF) == 0).astype(jnp.int32), axis=1
    )


@jax.jit
def stream_activity(streams: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-lane (toggles, zeros) of a (lanes, length) bf16 stream matrix.

    Toggles count transitions *within* each lane's sequence (length-1
    transitions per lane), exactly what a pipeline register at the array
    edge would experience as the stream passes through it.
    """
    if streams.ndim != 2:
        raise ValueError(f"streams must be 2-D, got {streams.shape}")
    lanes, _ = streams.shape
    bits = jax.lax.bitcast_convert_type(
        streams.astype(jnp.bfloat16), jnp.uint16
    )
    return pl.pallas_call(
        _activity_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((lanes,), jnp.int32),
            jax.ShapeDtypeStruct((lanes,), jnp.int32),
        ),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(bits)

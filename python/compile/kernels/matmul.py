"""L1 Pallas kernel: output-stationary tiled bf16 matmul.

This is the compute hot-spot of the paper's systolic array, re-expressed for
a TPU-style memory hierarchy (DESIGN.md §Hardware-Adaptation):

  * the paper's 16x16 PE array  ->  a (TILE_M, TILE_N) output block that
    stays resident ("output-stationary") while K blocks stream through;
  * the paper's West/North operand streaming  ->  the BlockSpec-scheduled
    HBM->VMEM movement of A row-blocks and B column-blocks;
  * the paper's zero-value clock gating  ->  block-level zero skipping:
    when an entire A block is zero the MXU dot is skipped (`pl.when`),
    which is the granularity a systolic TPU pipeline can actually exploit.

Numerics: operands are bfloat16, accumulation is float32 (MXU-style).
Kernels are always lowered with interpret=True: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness (not wallclock) is what the
interpret path validates. Real-TPU efficiency is *estimated* from the VMEM
footprint / MXU shape in DESIGN.md, never measured here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's SA is 16x16 PEs. We default the output tile to the same shape
# so one grid step corresponds to one SA tile of the GEMM tiling that the
# rust coordinator performs (rust/src/workload/tiler.rs).
TILE_M = 16
TILE_N = 16
TILE_K = 16


def _matmul_kernel(a_ref, b_ref, o_ref, *, skip_zero_blocks: bool):
    """One (i, j, k) grid step: o[i,j] += a[i,k] @ b[k,j].

    The (i, j) output block is output-stationary across the innermost k
    dimension, mirroring the paper's accumulation inside each PE.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def _mac():
        a = a_ref[...].astype(jnp.float32)
        b = b_ref[...].astype(jnp.float32)
        o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)

    if skip_zero_blocks:
        # Zero-value gating at block granularity: a block of zero inputs
        # contributes nothing; skip the MXU op entirely.
        nonzero = jnp.any(a_ref[...] != 0)

        @pl.when(nonzero)
        def _():
            _mac()
    else:
        _mac()


def _ceil_to(x: int, m: int) -> int:
    return (x + m - 1) // m * m


@functools.partial(
    jax.jit,
    static_argnames=("tile_m", "tile_n", "tile_k", "skip_zero_blocks"),
)
def matmul_bf16(
    a: jax.Array,
    b: jax.Array,
    *,
    tile_m: int = TILE_M,
    tile_n: int = TILE_N,
    tile_k: int = TILE_K,
    skip_zero_blocks: bool = False,
) -> jax.Array:
    """Tiled bf16 x bf16 -> f32 matmul via the Pallas kernel.

    Accepts any (M, K) x (K, N); pads to tile multiples and slices back.
    Inputs are cast to bfloat16 (the paper's arithmetic format); the
    accumulator is float32, as in the paper's PE (bf16 multiply, wider add).
    """
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes: {a.shape} x {b.shape}")
    m, k = a.shape
    _, n = b.shape
    a = a.astype(jnp.bfloat16)
    b = b.astype(jnp.bfloat16)

    mp, kp, np_ = _ceil_to(m, tile_m), _ceil_to(k, tile_k), _ceil_to(n, tile_n)
    a = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b = jnp.pad(b, ((0, kp - k), (0, np_ - n)))

    grid = (mp // tile_m, np_ // tile_n, kp // tile_k)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, skip_zero_blocks=skip_zero_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_k, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(a, b)
    return out[:m, :n]
